//! Fleet-wide availability index: the O(feasible + log N) candidate
//! pre-filter behind low-priority offload and churn rescue.
//!
//! The paper's LP scheduler and the rescue path both rank *every* up
//! device per candidate time-point (`earliest_availability` /
//! `peak_usage_in` per device), which is O(N) per time-point — the
//! dominant controller cost at fleet scale, and fatally so in the sharded
//! plane where each shard's `NetworkState` is fleet-sized with foreign
//! devices masked `Down`. The profiler (`util::profiler`) is what exposed
//! this; this module is what kills it.
//!
//! The index records, per up device, the latest reservation *end* on its
//! core calendar ([`crate::resources::CoreTimeline::last_end`]), sorted by
//! `(last_end, id)`. Windows are half-open, so every device whose
//! `last_end <= t` is **settled** at `t`: usage is zero, any core count up
//! to capacity is available immediately, and any window starting at or
//! after `t` sees zero peak usage. A `partition_point` therefore splits the
//! fleet into a settled prefix answered in O(1) per device — no calendar
//! walk — and an active suffix that pays the exact per-device scan. Under
//! the steady workloads the sweeps run, most of the fleet is settled at
//! any instant, so candidate selection scales with the *busy* devices, not
//! the fleet.
//!
//! Correctness is equivalence, not heuristics: the settled fast path emits
//! exactly the tuple the direct scan would have computed (busy/peak are
//! provably zero there), callers re-sort the merged candidates, and every
//! consumer keeps a direct-scan fallback behind [`set_enabled`] that the
//! equivalence harness (`PATS_EQ_INDEX`) and the property tests in this
//! module hold bit-identical.
//!
//! Caching mirrors `resources::pool`: entries are keyed by the state's
//! `(uid, version)` pair in a small thread-local cache. Every mutating
//! `NetworkState` method bumps `version`, so invalidation is correct by
//! construction — a stale index simply never matches again.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::atomic::{AtomicBool, Ordering};

use crate::state::NetworkState;
use crate::task::{DeviceId, Window};
use crate::time::SimTime;
use crate::util::profiler::{self, Counter};

/// Gates whether consumers (LP offload, rescue) use the index or the
/// direct O(N) scan. On by default; the equivalence harness flips it via
/// `PATS_EQ_INDEX` to prove both paths bit-identical.
static ENABLED: AtomicBool = AtomicBool::new(true);

/// Route candidate scans through the index (`true`, the default) or the
/// direct per-device scan (`false`). Both produce bit-identical results;
/// the toggle exists for differential testing and benchmarking.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Is the availability index in use?
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// One up device's entry in the index.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct IndexEntry {
    /// Latest reservation end on the device's core calendar
    /// ([`SimTime::ZERO`] for an empty calendar): the instant from which
    /// the device is completely idle.
    pub settled_at: SimTime,
    /// The device id.
    pub device: u32,
    /// The device's core capacity (cached so the settled fast path needs
    /// no state lookup).
    pub capacity: u32,
}

/// Snapshot of every *up* device's settle point, sorted by
/// `(settled_at, device)`.
#[derive(Debug, Clone)]
pub struct AvailabilityIndex {
    entries: Vec<IndexEntry>,
}

impl AvailabilityIndex {
    /// Build the index from a state snapshot: one entry per up device.
    /// O(N log N); amortised away by the `(uid, version)` cache.
    pub fn build(st: &NetworkState) -> AvailabilityIndex {
        let mut entries: Vec<IndexEntry> = st
            .up_devices()
            .map(|d| {
                let tl = st.device(d);
                IndexEntry {
                    settled_at: tl.last_end().unwrap_or(SimTime::ZERO),
                    device: d.0,
                    capacity: tl.capacity(),
                }
            })
            .collect();
        entries.sort_unstable_by_key(|e| (e.settled_at, e.device));
        AvailabilityIndex { entries }
    }

    /// Every entry, sorted by `(settled_at, device)`.
    pub fn entries(&self) -> &[IndexEntry] {
        &self.entries
    }

    /// Number of up devices indexed.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Is the index empty (no up devices)?
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Split into `(settled, active)` at time-point `t`: every device in
    /// the settled prefix has `settled_at <= t` (idle from `t` on); the
    /// active suffix still holds reservations ending after `t`. O(log N).
    pub fn split_settled(&self, t: SimTime) -> (&[IndexEntry], &[IndexEntry]) {
        let cut = self.entries.partition_point(|e| e.settled_at <= t);
        self.entries.split_at(cut)
    }
}

/// Thread-local cache entries kept. Sweeps interleave at most a few
/// states per thread (one per shard the thread touches plus the global
/// one), so a small cap bounds memory without hurting the hit rate —
/// mirrors `resources::pool::POOL_CAP`.
const CACHE_CAP: usize = 8;

thread_local! {
    static CACHE: RefCell<Vec<(u64, u64, Rc<AvailabilityIndex>)>> =
        const { RefCell::new(Vec::new()) };
}

/// The availability index for `st`'s exact `(uid, version)` snapshot:
/// served from the thread-local cache when this snapshot was indexed
/// before, rebuilt (and cached, displacing any stale entry for the same
/// state) otherwise. Always coherent — any state mutation bumps `version`,
/// so a cached index can never describe anything but the live calendars.
pub fn index_for(st: &NetworkState) -> Rc<AvailabilityIndex> {
    let (uid, version) = (st.uid(), st.version());
    if let Some(hit) = CACHE.with(|c| {
        c.borrow()
            .iter()
            .find(|(u, v, _)| *u == uid && *v == version)
            .map(|(_, _, idx)| Rc::clone(idx))
    }) {
        profiler::count(Counter::IndexHit, 1);
        return hit;
    }
    profiler::count(Counter::IndexMiss, 1);
    profiler::count(Counter::IndexBuild, 1);
    let idx = Rc::new(AvailabilityIndex::build(st));
    CACHE.with(|c| {
        let mut cache = c.borrow_mut();
        // A stale snapshot of the same state can never match again.
        cache.retain(|(u, _, _)| *u != uid);
        if cache.len() >= CACHE_CAP {
            cache.remove(0);
        }
        cache.push((uid, version, Rc::clone(&idx)));
    });
    idx
}

/// Rescue candidate scan: `(peak_usage_in(window), device)` for every up
/// device except `source`, in the exact tuples the direct scan produces
/// (unsorted — the caller sorts and truncates). Settled devices
/// (`settled_at <= window.start`) are emitted as `(0, d)` without touching
/// their calendars; active devices pay the exact per-device peak scan.
/// Falls back to the direct scan when the index is [disabled](set_enabled).
pub fn rescue_candidates(
    st: &NetworkState,
    source: DeviceId,
    window: &Window,
) -> Vec<(u32, u32)> {
    if !enabled() {
        return rescue_candidates_direct(st, source, window);
    }
    let idx = index_for(st);
    let (settled, active) = idx.split_settled(window.start);
    profiler::count(Counter::DevicesSettled, settled.len() as u64);
    profiler::count(Counter::DevicesScanned, active.len() as u64);
    let mut out = Vec::with_capacity(idx.len().saturating_sub(1));
    for e in settled {
        if e.device != source.0 {
            out.push((0, e.device));
        }
    }
    for e in active {
        if e.device != source.0 {
            out.push((st.device(DeviceId(e.device)).peak_usage_in(window), e.device));
        }
    }
    out
}

/// The legacy O(N) rescue scan the index replaces; kept as the
/// differential baseline.
fn rescue_candidates_direct(
    st: &NetworkState,
    source: DeviceId,
    window: &Window,
) -> Vec<(u32, u32)> {
    st.up_devices()
        .filter(|&d| d != source)
        .map(|d| (st.device(d).peak_usage_in(window), d.0))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SystemConfig;
    use crate::scheduler::plan::PlacementPlan;
    use crate::state::DeviceHealth;
    use crate::task::{Allocation, DeviceId, FailReason, Priority, TaskId, TaskSpec};
    use crate::time::SimDuration;
    use crate::util::prop::{run, Gen};

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }

    fn place(st: &mut NetworkState, device: u32, start: u64, end: u64, cores: u32) -> TaskId {
        let id = st.fresh_task_id();
        st.register_task(TaskSpec {
            id,
            frame: crate::task::FrameId(0),
            source: DeviceId(0),
            priority: Priority::Low,
            deadline: t(end),
            spawn: SimTime::ZERO,
            request: None,
        });
        let mut plan = PlacementPlan::new(st);
        plan.stage_placement(st, Allocation {
            task: id,
            device: DeviceId(device),
            window: Window::new(t(start), t(end)),
            cores,
            offloaded: false,
        })
        .expect("test placement fits");
        st.apply(plan).expect("test placement commits");
        id
    }

    #[test]
    fn index_matches_state_and_splits_correctly() {
        let mut cfg = SystemConfig::default();
        cfg.devices = 6;
        let mut st = NetworkState::new(&cfg);
        place(&mut st, 1, 0, 500, 2);
        place(&mut st, 3, 100, 900, 2);
        st.mark_device_down(DeviceId(5), SimTime::ZERO);
        let idx = AvailabilityIndex::build(&st);
        // Only up devices; sorted by (settled_at, id); empty calendars at ZERO.
        let devs: Vec<u32> = idx.entries().iter().map(|e| e.device).collect();
        assert_eq!(devs, vec![0, 2, 4, 1, 3]);
        assert_eq!(idx.entries()[3].settled_at, t(500));
        assert_eq!(idx.entries()[4].settled_at, t(900));
        assert_eq!(idx.len(), 5);
        let (settled, active) = idx.split_settled(t(500));
        assert_eq!(settled.len(), 4, "dev 1 settles exactly at its last end");
        assert_eq!(active.len(), 1);
        let (settled, active) = idx.split_settled(t(499));
        assert_eq!((settled.len(), active.len()), (3, 2));
        // The settled-device lemma, against the live calendars.
        for e in idx.split_settled(t(600)).0 {
            let d = st.device(DeviceId(e.device));
            assert_eq!(d.usage_at(t(600)), 0);
            assert_eq!(d.earliest_availability(t(600), e.capacity), Some(t(600)));
            assert_eq!(d.peak_usage_in(&Window::new(t(600), t(5_000))), 0);
        }
    }

    #[test]
    fn cache_hits_same_snapshot_and_invalidates_on_version_bump() {
        let mut cfg = SystemConfig::default();
        cfg.devices = 4;
        let mut st = NetworkState::new(&cfg);
        place(&mut st, 1, 0, 400, 2);
        let a = index_for(&st);
        let b = index_for(&st);
        assert!(Rc::ptr_eq(&a, &b), "same (uid, version) must hit the cache");
        // Any mutation bumps the version: the next lookup rebuilds.
        st.set_device_health(DeviceId(3), DeviceHealth::Draining);
        let c = index_for(&st);
        assert!(!Rc::ptr_eq(&a, &c), "version bump must invalidate");
        assert_eq!(c.len(), 3, "the drained device left the index");
        // A different state never matches this one's entries.
        let other = NetworkState::new(&cfg);
        let d = index_for(&other);
        assert!(!Rc::ptr_eq(&c, &d));
        assert_eq!(d.len(), 4);
    }

    /// The heart of the bit-identity claim: under random place / complete /
    /// fail / preempt / prune / churn sequences, the indexed rescue scan
    /// equals the direct scan tuple-for-tuple, and every index entry agrees
    /// with the live calendar it summarises.
    #[test]
    fn prop_indexed_scan_equals_direct_scan_under_random_ops() {
        run("availability index ≡ direct scan", 120, |g: &mut Gen| {
            let mut cfg = SystemConfig::default();
            cfg.devices = g.usize(2, 10);
            let mut st = NetworkState::new(&cfg);
            let mut live: Vec<(TaskId, u32)> = Vec::new();
            for _ in 0..g.usize(1, 40) {
                match g.usize(0, 5) {
                    0 | 1 => {
                        let d = g.u64(0, cfg.devices as u64 - 1) as u32;
                        if st.device_is_up(DeviceId(d)) {
                            let start = g.u64(0, 2_000);
                            let end = start + g.u64(1, 2_000);
                            let cores = g.u64(1, 2) as u32;
                            let tl = st.device(DeviceId(d));
                            if tl.fits(&Window::new(t(start), t(end)), cores) {
                                let id = place(&mut st, d, start, end, cores);
                                live.push((id, d));
                            }
                        }
                    }
                    2 => {
                        if !live.is_empty() {
                            let (id, _) = live.swap_remove(g.usize(0, live.len() - 1));
                            if g.bool(0.5) {
                                st.complete_task(id, t(g.u64(0, 4_000)));
                            } else {
                                st.fail_task(id, FailReason::Violated, t(g.u64(0, 4_000)));
                            }
                        }
                    }
                    3 => {
                        if !live.is_empty() {
                            let (id, _) = live.swap_remove(g.usize(0, live.len() - 1));
                            let _ = st.preempt_task(id, t(g.u64(0, 4_000)));
                        }
                    }
                    4 => {
                        st.prune_before(t(g.u64(0, 3_000)));
                    }
                    _ => {
                        let d = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                        match g.usize(0, 2) {
                            0 => {
                                st.mark_device_down(d, t(g.u64(0, 4_000)));
                                live.retain(|&(_, dev)| dev != d.0);
                            }
                            1 => st.set_device_health(d, DeviceHealth::Up),
                            _ => {
                                if st.device(d).is_empty() {
                                    st.set_device_health(d, DeviceHealth::Draining);
                                }
                            }
                        }
                    }
                }
                // Index entries agree with the live calendars.
                let idx = AvailabilityIndex::build(&st);
                assert_eq!(idx.len(), st.up_devices().count());
                for e in idx.entries() {
                    let tl = st.device(DeviceId(e.device));
                    assert!(st.device_is_up(DeviceId(e.device)));
                    assert_eq!(e.settled_at, tl.last_end().unwrap_or(SimTime::ZERO));
                    assert_eq!(e.capacity, tl.capacity());
                    assert_eq!(tl.usage_at(e.settled_at), 0, "settled ⇒ idle");
                }
                assert!(
                    idx.entries()
                        .windows(2)
                        .all(|p| (p[0].settled_at, p[0].device) < (p[1].settled_at, p[1].device)),
                    "sorted by (settled_at, device)"
                );
                // Indexed rescue scan ≡ direct scan after the caller's sort.
                let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                let ws = g.u64(0, 4_000);
                let window = Window::new(t(ws), t(ws + g.u64(1, 2_000)));
                let mut via_index = rescue_candidates(&st, source, &window);
                let mut direct = rescue_candidates_direct(&st, source, &window);
                via_index.sort_unstable();
                direct.sort_unstable();
                assert_eq!(via_index, direct, "indexed scan diverged from direct scan");
            }
        });
    }

    #[test]
    fn cached_index_stays_coherent_across_random_mutation_interleavings() {
        run("cache coherence", 80, |g: &mut Gen| {
            let mut cfg = SystemConfig::default();
            cfg.devices = g.usize(2, 6);
            let mut st = NetworkState::new(&cfg);
            for _ in 0..g.usize(1, 15) {
                // Random mutation (or none — exercising repeated hits).
                if g.bool(0.7) {
                    let d = g.u64(0, cfg.devices as u64 - 1) as u32;
                    if st.device_is_up(DeviceId(d)) {
                        let start = g.u64(0, 1_000);
                        let end = start + g.u64(1, 1_000);
                        if st.device(DeviceId(d)).fits(&Window::new(t(start), t(end)), 1) {
                            place(&mut st, d, start, end, 1);
                        }
                    } else {
                        st.set_device_health(DeviceId(d), DeviceHealth::Up);
                    }
                }
                // Whatever the cache serves must equal a fresh build.
                let cached = index_for(&st);
                let fresh = AvailabilityIndex::build(&st);
                assert_eq!(cached.entries(), fresh.entries(), "stale index served");
            }
        });
    }

    #[test]
    fn charge_link_message_invalidates_like_any_mutation() {
        // The link calendar doesn't feed the index, but its mutations still
        // bump the version — the index must simply rebuild to an equal
        // value, never serve across a key change.
        let cfg = SystemConfig::default();
        let mut st = NetworkState::new(&cfg);
        let a = index_for(&st);
        st.charge_link_message(
            SimTime::ZERO,
            SimDuration::from_micros(10),
            crate::resources::SlotKind::PollMsg,
            TaskId(1),
        );
        let b = index_for(&st);
        assert!(!Rc::ptr_eq(&a, &b));
        assert_eq!(a.entries(), b.entries());
    }
}
