//! Time-slotted resource reservation calendars.
//!
//! The controller allocates two resource types (§3): the shared wireless
//! **link** (exclusive — no two transfers overlap) and each device's **CPU
//! cores** (additive — concurrent reservations as long as the core sum stays
//! within capacity). Slots are variable-length and carry the padding the
//! paper adds for run-time variation.

mod cores;
mod timeline;

pub use cores::CoreTimeline;
pub use timeline::{SlotKind, Timeline};
