//! Time-slotted resource reservation calendars.
//!
//! The controller allocates two resource types (§3): the shared wireless
//! **link** (exclusive — no two transfers overlap) and each device's **CPU
//! cores** (additive — concurrent reservations as long as the core sum stays
//! within capacity). Slots are variable-length and carry the padding the
//! paper adds for run-time variation.
//!
//! The link calendar is gap-indexed for fleet scale — see [`Timeline`] for
//! the design and `rust/tests/prop_timeline_equivalence.rs` for the
//! behavioural proof against the seed's linear scan.

pub mod avail;
mod cores;
pub(crate) mod pool;
mod timeline;

pub use cores::{CoreSlot, CoreTimeline};
pub use timeline::{Slot, SlotKind, Timeline};
