//! Thread-local scratch-timeline pool for the planning layer.
//!
//! Candidate-plan search (rescue, preemption, degraded-variant retries)
//! opens many [`super::Timeline`] scratch copies and drops most of them:
//! every losing candidate used to pay a full link-calendar clone — the
//! dominant placement cost named in KNOWN_ISSUES §Plan cost model. The
//! pool turns that loser cost into an undo-log replay: a plan that rolls
//! its scratch timeline back to the base state returns it here, and the
//! next plan opened against the *same* base state borrows it instead of
//! cloning.
//!
//! Keying and safety:
//!
//! * Entries are keyed by `(state uid, state version)`. The uid is minted
//!   per [`crate::state::NetworkState`] from a process-wide counter and
//!   the version is the state's mutation stamp, so a pooled timeline can
//!   only ever be handed to a borrower whose base state has **bit-identical
//!   link reservations** — a stale entry (the state mutated, or a
//!   different state entirely) simply never matches and ages out.
//! * The pool is thread-local. Shard decision sweeps run one shard per
//!   scoped thread; each thread's searches only ever open plans against
//!   that shard's state, so entries never cross shards and no locking is
//!   needed.
//! * Only *fully rolled back* timelines are returned (the plan layer
//!   replays its undo log and verifies every step; on any rollback
//!   failure the timeline is dropped, not pooled). Debug builds
//!   additionally verify content equality against the live state on every
//!   pool hit.

use std::cell::RefCell;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::Timeline;

/// Default entries kept per thread. Candidate searches hold at most a
/// handful of live plans at once (`RESCUE_TOP_K` + the shared plan), so a
/// small cap bounds memory without hurting the hit rate.
const DEFAULT_POOL_CAP: usize = 8;

/// Live capacity (`[sharding] pool_capacity`). Process-global like the
/// profiler toggle: the pool is a pure cache, so a capacity change can
/// never affect scheduling output — only the hit rate. The executor's
/// long-lived workers touch every shard, so sizing this to ≥ K keeps one
/// pooled timeline per shard resident per worker thread.
static POOL_CAP: AtomicUsize = AtomicUsize::new(DEFAULT_POOL_CAP);

/// Set the per-thread pool capacity (clamped to ≥ 1). Called from the
/// controller/plane constructors with `sharding.pool_capacity`.
pub(crate) fn set_capacity(cap: usize) {
    POOL_CAP.store(cap.max(1), Ordering::Relaxed);
}

/// Current per-thread pool capacity.
pub(crate) fn capacity() -> usize {
    POOL_CAP.load(Ordering::Relaxed)
}

thread_local! {
    static POOL: RefCell<Vec<(u64, u64, Timeline)>> = const { RefCell::new(Vec::new()) };
}

/// Borrow a pooled scratch timeline for the state identified by
/// `(uid, version)`, if one is available.
pub(crate) fn acquire(uid: u64, version: u64) -> Option<Timeline> {
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        let idx = pool.iter().position(|(u, v, _)| *u == uid && *v == version)?;
        Some(pool.swap_remove(idx).2)
    })
}

/// Return a fully rolled-back scratch timeline to the pool. Oldest entries
/// are evicted beyond the configured [`capacity`].
pub(crate) fn release(uid: u64, version: u64, tl: Timeline) {
    let cap = capacity();
    POOL.with(|p| {
        let mut pool = p.borrow_mut();
        while pool.len() >= cap {
            pool.remove(0);
        }
        pool.push((uid, version, tl));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::resources::SlotKind;
    use crate::task::TaskId;
    use crate::time::{SimDuration, SimTime};

    #[test]
    fn acquire_matches_key_exactly() {
        let mut tl = Timeline::new();
        tl.reserve(SimTime::ZERO, SimDuration::from_micros(5), SlotKind::PollMsg, TaskId(1))
            .unwrap();
        release(77, 3, tl.clone());
        assert!(acquire(77, 4).is_none(), "version mismatch must miss");
        assert!(acquire(78, 3).is_none(), "uid mismatch must miss");
        let got = acquire(77, 3).expect("exact key must hit");
        assert!(got.same_reservations(&tl));
        assert!(acquire(77, 3).is_none(), "an entry is handed out once");
    }

    #[test]
    fn pool_is_bounded_by_configured_capacity() {
        let cap = capacity() as u64;
        for i in 0..(cap + 5) {
            release(1000 + i, 0, Timeline::new());
        }
        // The oldest entries were evicted; the newest survive.
        assert!(acquire(1000, 0).is_none());
        assert!(acquire(1000 + cap + 4, 0).is_some());
        // Drain whatever remains so other tests see a clean pool.
        for i in 0..(cap + 5) {
            let _ = acquire(1000 + i, 0);
        }
        // Capacity is clamped to >= 1 and releases honour the live value.
        // (Restore the default afterwards: the knob is process-global and
        // other tests in this binary assume it.)
        let before = capacity();
        set_capacity(0);
        assert_eq!(capacity(), 1);
        release(2000, 0, Timeline::new());
        release(2001, 0, Timeline::new());
        assert!(acquire(2000, 0).is_none(), "cap 1 keeps only the newest");
        assert!(acquire(2001, 0).is_some());
        set_capacity(before);
    }
}
