//! Per-device CPU-core reservation timeline (additive resource).
//!
//! Unlike the link, a device can host several tasks at once as long as the
//! *sum of their cores* never exceeds capacity (§4: "if the total core usage
//! of existing tasks that overlap with the processing time-slot plus the
//! additional core ... does not exceed the source device's capacity").

use crate::error::{Error, Result};
use crate::task::{TaskId, Window};
use crate::time::SimTime;

/// One core reservation.
#[derive(Debug, Clone)]
pub struct CoreSlot {
    /// Reserved processing window.
    pub window: Window,
    /// Cores held throughout the window.
    pub cores: u32,
    /// The owning task.
    pub task: TaskId,
    /// Absolute deadline of the owning task — cached here so preemption
    /// victim selection ("farthest deadline") needs no registry lookup.
    pub deadline: SimTime,
    /// Whether the owning task may be preempted (low-priority only).
    pub preemptible: bool,
}

/// Additive reservation calendar for one device's cores.
#[derive(Debug, Clone)]
pub struct CoreTimeline {
    capacity: u32,
    /// Sorted by window start (overlaps allowed — that's the point).
    slots: Vec<CoreSlot>,
}

impl CoreTimeline {
    /// An empty calendar for a device with `capacity` cores.
    pub fn new(capacity: u32) -> CoreTimeline {
        assert!(capacity > 0);
        CoreTimeline { capacity, slots: Vec::new() }
    }

    /// Total cores of the device.
    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    /// Number of reservations on the calendar.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Is the calendar empty?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// The shared step-function evaluator behind every usage/fit query:
    /// usage at instant `t`, optionally pretending `excluded`'s
    /// reservations do not exist.
    fn usage_at_excluding(&self, t: SimTime, excluded: Option<TaskId>) -> u32 {
        self.slots
            .iter()
            .take_while(|s| s.window.start <= t)
            .filter(|s| Some(s.task) != excluded && s.window.contains(t))
            .map(|s| s.cores)
            .sum()
    }

    /// Peak usage over `window`, optionally excluding one task: evaluated
    /// at the window start and every reservation start inside the window
    /// (usage is a step function that only increases at starts).
    fn peak_usage_in_excluding(&self, window: &Window, excluded: Option<TaskId>) -> u32 {
        let mut peak = self.usage_at_excluding(window.start, excluded);
        for s in &self.slots {
            if s.window.start >= window.end {
                break;
            }
            if window.contains(s.window.start) {
                peak = peak.max(self.usage_at_excluding(s.window.start, excluded));
            }
        }
        peak
    }

    /// Peak core usage over `window` from existing reservations.
    ///
    /// Exact: evaluates usage at every reservation start within the window.
    /// O(k²) in the overlapping reservations, but k stays tiny (≤ a
    /// handful per device after pruning); a sweep-line variant was measured
    /// ~4 % slower at real workload sizes (EXPERIMENTS.md §Perf iteration 3).
    pub fn peak_usage_in(&self, window: &Window) -> u32 {
        self.peak_usage_in_excluding(window, None)
    }

    /// Core usage at one instant.
    pub fn usage_at(&self, t: SimTime) -> u32 {
        self.usage_at_excluding(t, None)
    }

    /// Can `cores` more cores fit throughout `window`?
    pub fn fits(&self, window: &Window, cores: u32) -> bool {
        cores <= self.capacity && self.peak_usage_in(window) + cores <= self.capacity
    }

    /// Read-only eviction probe: would `cores` more cores fit throughout
    /// `window` if `excluded`'s reservations were removed first?
    ///
    /// This answers "is this single eviction sufficient?" without mutating
    /// anything — the candidate-plan searches (rescue, workstealer
    /// preemption) use it to skip building plans for candidates whose
    /// eviction cannot make room. Exact, not a heuristic: it shares the
    /// step-function evaluator with [`CoreTimeline::fits`], minus the
    /// excluded task's contribution.
    pub fn fits_without(&self, window: &Window, cores: u32, excluded: TaskId) -> bool {
        cores <= self.capacity
            && self.peak_usage_in_excluding(window, Some(excluded)) + cores <= self.capacity
    }

    /// Earliest instant `>= after` at which `cores` additional cores are
    /// free — i.e. the earliest a reservation of that width could *start*
    /// (it may still be interrupted later; use [`CoreTimeline::fits`] for a
    /// full-window check). Returns `None` only when `cores` exceeds
    /// capacity.
    ///
    /// This is the fleet-scale candidate pre-filter primitive: usage is a
    /// step function that only decreases at reservation ends, so if
    /// `earliest_availability(tp, cores) + slot` already misses a deadline,
    /// no feasible window on this device exists and the scheduler can skip
    /// it without paying the full placement search (see
    /// `scheduler::low_priority`).
    pub fn earliest_availability(&self, after: SimTime, cores: u32) -> Option<SimTime> {
        if cores > self.capacity {
            return None;
        }
        if self.usage_at(after) + cores <= self.capacity {
            return Some(after);
        }
        // Usage only drops at reservation ends; probe them in time order.
        let mut ends: Vec<SimTime> = self
            .slots
            .iter()
            .map(|s| s.window.end)
            .filter(|&e| e > after)
            .collect();
        ends.sort_unstable();
        ends.dedup();
        for e in ends {
            if self.usage_at(e) + cores <= self.capacity {
                return Some(e);
            }
        }
        // Unreachable: past the last reservation end the usage is zero, and
        // that end is always probed when `after` itself is over-committed.
        None
    }

    /// Reserve `cores` cores for `task` over `window`.
    pub fn reserve(
        &mut self,
        window: Window,
        cores: u32,
        task: TaskId,
        deadline: SimTime,
        preemptible: bool,
    ) -> Result<()> {
        if !self.fits(&window, cores) {
            return Err(Error::Allocation(format!(
                "core reservation {cores}c over {window:?} exceeds capacity {}",
                self.capacity
            )));
        }
        let idx = self.slots.partition_point(|s| s.window.start <= window.start);
        self.slots.insert(
            idx,
            CoreSlot { window, cores, task, deadline, preemptible },
        );
        Ok(())
    }

    /// Remove the reservation(s) of `task`; returns how many were removed.
    pub fn remove_task(&mut self, task: TaskId) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.task != task);
        before - self.slots.len()
    }

    /// Reservations overlapping `window`.
    pub fn overlapping<'a>(&'a self, window: &'a Window) -> impl Iterator<Item = &'a CoreSlot> {
        self.slots
            .iter()
            .take_while(move |s| s.window.start < window.end)
            .filter(move |s| s.window.overlaps(window))
    }

    /// Preemption candidates overlapping `window`: preemptible slots,
    /// sorted by *descending deadline* — the paper selects "a single
    /// conflicting task with the farthest deadline" (§4).
    pub fn preemption_candidates<'a>(&'a self, window: &Window) -> Vec<&'a CoreSlot> {
        let mut v: Vec<&'a CoreSlot> = self
            .slots
            .iter()
            .take_while(|s| s.window.start < window.end)
            .filter(|s| s.window.overlaps(window) && s.preemptible)
            .collect();
        v.sort_by(|a, b| b.deadline.cmp(&a.deadline).then(a.task.cmp(&b.task)));
        v
    }

    /// Completion time-points of reservations in `(after, until]` — the
    /// search set of the low-priority scheduler (§4: "a set of time points,
    /// representing the completion of existing tasks and the release of
    /// their occupied resources").
    pub fn completion_points(&self, after: SimTime, until: SimTime) -> Vec<SimTime> {
        let mut v: Vec<SimTime> = self
            .slots
            .iter()
            .map(|s| s.window.end)
            .filter(|&e| e > after && e <= until)
            .collect();
        v.sort_unstable();
        v.dedup();
        v
    }

    /// Drop every reservation (device failure reclamation: a dead device's
    /// calendar must not keep phantom slots alive).
    pub fn clear(&mut self) -> usize {
        let n = self.slots.len();
        self.slots.clear();
        n
    }

    /// Drop reservations ending at or before `t`.
    pub fn prune_before(&mut self, t: SimTime) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.window.end > t);
        before - self.slots.len()
    }

    /// All reservations (sorted by start).
    pub fn slots(&self) -> &[CoreSlot] {
        &self.slots
    }

    /// The latest reservation *end* on the calendar, or `None` when empty.
    ///
    /// Windows are half-open, so at any instant `t >= last_end()` the
    /// device is completely idle: `usage_at(t) == 0`,
    /// `earliest_availability(t, c) == Some(t)` for every `c <= capacity`,
    /// and `peak_usage_in(w) == 0` for any window starting at or after it.
    /// The fleet-wide availability index keys on this to answer "which
    /// devices are settled by time-point `t`" without walking calendars
    /// (see `resources::avail`).
    ///
    /// Slots are sorted by *start*, so this scans all of them — O(k) in
    /// the (post-prune, tiny) reservation count.
    pub fn last_end(&self) -> Option<SimTime> {
        self.slots.iter().map(|s| s.window.end).max()
    }

    /// Debug invariant: sorted by start; capacity never exceeded at any
    /// reservation boundary.
    pub fn check_invariants(&self) -> Result<()> {
        for pair in self.slots.windows(2) {
            if pair[0].window.start > pair[1].window.start {
                return Err(Error::Invariant("core timeline not sorted".into()));
            }
        }
        for s in &self.slots {
            let u = self.usage_at(s.window.start);
            if u > self.capacity {
                return Err(Error::Invariant(format!(
                    "capacity exceeded at {}: {u} > {}",
                    s.window.start, self.capacity
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }
    fn w(a: u64, b: u64) -> Window {
        Window::new(t(a), t(b))
    }

    fn reserve(tl: &mut CoreTimeline, win: Window, cores: u32, id: u64, dl: u64) {
        tl.reserve(win, cores, TaskId(id), t(dl), true).unwrap();
    }

    #[test]
    fn usage_accumulates() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 2, 1, 100);
        reserve(&mut tl, w(50, 150), 2, 2, 150);
        assert_eq!(tl.usage_at(t(25)), 2);
        assert_eq!(tl.usage_at(t(75)), 4);
        assert_eq!(tl.usage_at(t(120)), 2);
        assert_eq!(tl.usage_at(t(150)), 0, "half-open end");
        tl.check_invariants().unwrap();
    }

    #[test]
    fn peak_usage_catches_interior_spikes() {
        let mut tl = CoreTimeline::new(8);
        reserve(&mut tl, w(0, 100), 2, 1, 100);
        reserve(&mut tl, w(40, 60), 4, 2, 60);
        // Window [20, 80) sees the spike to 6 even though usage at 20 is 2.
        assert_eq!(tl.peak_usage_in(&w(20, 80)), 6);
        assert_eq!(tl.peak_usage_in(&w(60, 80)), 2);
    }

    #[test]
    fn fits_respects_capacity() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 3, 1, 100);
        assert!(tl.fits(&w(0, 100), 1));
        assert!(!tl.fits(&w(0, 100), 2));
        assert!(tl.fits(&w(100, 200), 4), "after release everything is free");
        assert!(!tl.fits(&w(0, 10), 5), "more than capacity never fits");
    }

    #[test]
    fn fits_without_excludes_exactly_one_task() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 2, 1, 100); // victim: 2 cores
        reserve(&mut tl, w(40, 60), 2, 2, 60); // bystander spike: 2 cores
        assert!(!tl.fits(&w(0, 100), 3), "full window cannot host 3 more cores");
        // Without the victim, the spike still caps the window at 2 free.
        assert!(tl.fits_without(&w(0, 100), 2, TaskId(1)));
        assert!(!tl.fits_without(&w(0, 100), 3, TaskId(1)), "spike still blocks");
        // Excluding the spike instead frees its slice only.
        assert!(tl.fits_without(&w(40, 60), 2, TaskId(2)));
        // Excluding an absent task degenerates to plain `fits`.
        assert_eq!(
            tl.fits_without(&w(0, 100), 1, TaskId(99)),
            tl.fits(&w(0, 100), 1)
        );
        // Over capacity is never feasible, eviction or not.
        assert!(!tl.fits_without(&w(0, 10), 5, TaskId(1)));
    }

    #[test]
    fn reserve_rejects_over_capacity() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 4, 1, 100);
        assert!(tl
            .reserve(w(50, 150), 1, TaskId(2), t(150), true)
            .is_err());
        // Non-overlapping is fine.
        assert!(tl.reserve(w(100, 200), 4, TaskId(2), t(200), true).is_ok());
    }

    #[test]
    fn remove_task_releases_cores() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 4, 1, 100);
        assert_eq!(tl.remove_task(TaskId(1)), 1);
        assert!(tl.fits(&w(0, 100), 4));
    }

    #[test]
    fn preemption_candidates_sorted_by_farthest_deadline() {
        let mut tl = CoreTimeline::new(8);
        reserve(&mut tl, w(0, 100), 2, 1, 300);
        reserve(&mut tl, w(0, 100), 2, 2, 500); // farthest deadline
        reserve(&mut tl, w(0, 100), 2, 3, 400);
        tl.reserve(w(0, 100), 1, TaskId(4), t(900), false).unwrap(); // HP: not preemptible
        let cands = tl.preemption_candidates(&w(10, 20));
        let ids: Vec<u64> = cands.iter().map(|s| s.task.0).collect();
        assert_eq!(ids, vec![2, 3, 1]);
    }

    #[test]
    fn completion_points_sorted_unique_bounded() {
        let mut tl = CoreTimeline::new(8);
        reserve(&mut tl, w(0, 100), 2, 1, 100);
        reserve(&mut tl, w(0, 100), 2, 2, 100); // duplicate end
        reserve(&mut tl, w(0, 250), 2, 3, 250);
        reserve(&mut tl, w(0, 400), 2, 4, 400); // beyond `until`
        assert_eq!(tl.completion_points(t(0), t(300)), vec![t(100), t(250)]);
        assert_eq!(tl.completion_points(t(100), t(300)), vec![t(250)], "after is exclusive");
    }

    #[test]
    fn prune_drops_finished() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 50), 2, 1, 50);
        reserve(&mut tl, w(60, 100), 2, 2, 100);
        assert_eq!(tl.prune_before(t(55)), 1);
        assert_eq!(tl.len(), 1);
    }

    #[test]
    fn zero_duration_window_fits_anywhere_under_capacity() {
        let tl = CoreTimeline::new(4);
        assert!(tl.fits(&w(10, 10), 4));
    }

    #[test]
    fn earliest_availability_tracks_release_points() {
        let mut tl = CoreTimeline::new(4);
        reserve(&mut tl, w(0, 100), 4, 1, 100);
        reserve(&mut tl, w(100, 200), 2, 2, 200);
        // Fully booked until 100: no room for even one core before then.
        assert_eq!(tl.earliest_availability(t(10), 1), Some(t(100)));
        // Two cores are free in [100, 200); four only after 200.
        assert_eq!(tl.earliest_availability(t(10), 2), Some(t(100)));
        assert_eq!(tl.earliest_availability(t(10), 3), Some(t(200)));
        assert_eq!(tl.earliest_availability(t(10), 4), Some(t(200)));
        // Idle point: immediately available.
        assert_eq!(tl.earliest_availability(t(300), 4), Some(t(300)));
        // Over capacity: never.
        assert_eq!(tl.earliest_availability(t(0), 5), None);
    }

    #[test]
    fn earliest_availability_on_empty_timeline() {
        let tl = CoreTimeline::new(4);
        assert_eq!(tl.earliest_availability(t(7), 4), Some(t(7)));
    }

    #[test]
    fn last_end_is_max_end_not_last_slot() {
        let mut tl = CoreTimeline::new(8);
        assert_eq!(tl.last_end(), None);
        // A later-starting slot can end *earlier* — sort is by start.
        reserve(&mut tl, w(0, 500), 2, 1, 500);
        reserve(&mut tl, w(100, 200), 2, 2, 200);
        assert_eq!(tl.last_end(), Some(t(500)));
        // Past last_end the settled-device lemma holds.
        assert_eq!(tl.usage_at(t(500)), 0, "half-open end");
        assert_eq!(tl.earliest_availability(t(500), 8), Some(t(500)));
        assert_eq!(tl.peak_usage_in(&w(500, 900)), 0);
        tl.remove_task(TaskId(1));
        assert_eq!(tl.last_end(), Some(t(200)));
        tl.clear();
        assert_eq!(tl.last_end(), None);
    }
}
