//! Exclusive-resource reservation timeline (the shared wireless link).
//!
//! Variable-length, non-overlapping, half-open slots. The controller
//! reserves one slot per message: allocation messages, input transfers,
//! state updates, preemption notices (§3.1).
//!
//! # Fleet-scale storage
//!
//! The seed implementation kept a sorted `Vec<Slot>` and found free space
//! with a linear gap scan — fine for the paper's four Raspberry Pis, but
//! the shared link of a 1024-device fleet holds thousands of live
//! reservations and the scan (plus the `Vec` insert memmove) made every
//! scheduling decision O(n). This version is **gap-indexed**:
//!
//! * `slots` — a `BTreeMap` keyed by start time (starts are unique because
//!   slots are non-overlapping and non-empty), giving O(log n)
//!   insert/remove/neighbour lookup.
//! * `gaps` — the exact complement of `slots` over `[0, u64::MAX)`
//!   microseconds, also keyed by start. The final gap always ends at
//!   `u64::MAX` (the open future).
//! * `gaps_by_len` — gap starts bucketed by `⌊log₂(length)⌋`. A fit query
//!   for duration `d` only has to consider the first gap after the query
//!   point in each bucket `≥ ⌊log₂ d⌋`: buckets strictly above are
//!   guaranteed to fit, and only the one ambiguous bucket (lengths within
//!   2× of `d`) needs individual length checks.
//! * `by_owner` — task → slot starts, so `remove_owner` touches only that
//!   owner's slots instead of scanning the calendar.
//!
//! `earliest_fit` and `reserve` are O(log n) (plus the one ambiguous
//! bucket, which is rarely populated in practice); `remove_owner` and
//! `prune_before` are O(k log n) in the slots actually removed. The
//! behavioural contract is identical to the linear implementation —
//! `rust/tests/prop_timeline_equivalence.rs` checks every operation against
//! a re-implementation of the seed's linear scan on random workloads.

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::error::{Error, Result};
use crate::task::{TaskId, Window};
use crate::time::{SimDuration, SimTime};

/// What a link slot carries (sizes differ per kind — see `net`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SlotKind {
    /// Controller → device: high-priority allocation decision.
    HpAllocMsg,
    /// Controller → device: low-priority allocation decision.
    LpAllocMsg,
    /// Device → device: input image transfer for an offloaded task.
    InputTransfer,
    /// Device → controller: status update on task completion.
    StateUpdate,
    /// Controller → device: preemption notice.
    PreemptMsg,
    /// Workstealer poll: "do you have work?" (decentralised baseline).
    PollMsg,
}

/// One reserved slot.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Slot {
    /// The reserved half-open window.
    pub window: Window,
    /// What the slot carries.
    pub kind: SlotKind,
    /// The task this slot serves.
    pub owner: TaskId,
}

/// Bucket index for a gap of `len` microseconds: `⌊log₂ len⌋`.
#[inline]
fn len_class(len: u64) -> usize {
    debug_assert!(len > 0, "zero-length gap has no bucket");
    63 - len.leading_zeros() as usize
}

/// A sorted, non-overlapping reservation calendar for an exclusive
/// resource, with a free-gap index for fleet-scale fit queries (see the
/// module docs for the design).
#[derive(Debug, Clone)]
pub struct Timeline {
    /// start → slot; starts are unique (slots are non-overlapping and
    /// non-empty).
    slots: BTreeMap<SimTime, Slot>,
    /// Free-gap complement of `slots`: gap start (µs) → gap end (µs).
    /// Tiles `[0, u64::MAX)` exactly; zero-length gaps are never stored.
    gaps: BTreeMap<u64, u64>,
    /// Gap starts bucketed by `len_class(gap length)`; 64 buckets.
    gaps_by_len: Vec<BTreeSet<u64>>,
    /// Owner → starts of its slots (insertion order).
    by_owner: HashMap<TaskId, Vec<SimTime>>,
}

impl Default for Timeline {
    fn default() -> Timeline {
        Timeline::new()
    }
}

impl Timeline {
    /// An empty calendar: one free gap covering all of time.
    pub fn new() -> Timeline {
        let mut tl = Timeline {
            slots: BTreeMap::new(),
            gaps: BTreeMap::new(),
            gaps_by_len: vec![BTreeSet::new(); 64],
            by_owner: HashMap::new(),
        };
        tl.gap_insert(0, u64::MAX);
        tl
    }

    /// Number of reserved slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// Is the calendar empty?
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    // ---- gap index internals --------------------------------------------

    fn gap_insert(&mut self, start: u64, end: u64) {
        debug_assert!(end > start, "gap [{start}, {end}) is empty or inverted");
        self.gaps.insert(start, end);
        self.gaps_by_len[len_class(end - start)].insert(start);
    }

    fn gap_remove(&mut self, start: u64) -> u64 {
        let end = self.gaps.remove(&start).expect("gap index corrupt");
        self.gaps_by_len[len_class(end - start)].remove(&start);
        end
    }

    /// Return `[start, end)` to the free pool, coalescing with any
    /// adjacent gaps.
    fn release_window(&mut self, start: u64, end: u64) {
        let mut lo = start;
        let mut hi = end;
        // A gap ending exactly at `start` merges from the left.
        if let Some((&gs, &ge)) = self.gaps.range(..start).next_back() {
            if ge == start {
                self.gap_remove(gs);
                lo = gs;
            }
        }
        // A gap starting exactly at `end` merges from the right.
        if self.gaps.contains_key(&end) {
            hi = self.gap_remove(end);
        }
        self.gap_insert(lo, hi);
    }

    /// Remove the slot starting at `start` and free its window.
    fn remove_slot(&mut self, start: SimTime) -> Slot {
        let slot = self.slots.remove(&start).expect("slot index corrupt");
        self.release_window(slot.window.start.0, slot.window.end.0);
        slot
    }

    // ---- queries ---------------------------------------------------------

    /// Earliest start `>= not_before` where a slot of `dur` fits.
    ///
    /// Answered from the gap index in O(log n): the gap containing
    /// `not_before` is probed directly, then each length bucket that can
    /// hold `dur` contributes its first gap after `not_before`. Only the
    /// one ambiguous bucket (gap lengths within 2× of `dur`) needs
    /// per-gap length checks.
    pub fn earliest_fit(&self, not_before: SimTime, dur: SimDuration) -> SimTime {
        let nb = not_before.0;
        let need = dur.0;
        if need == 0 {
            // Degenerate zero-length request: any instant not strictly
            // inside a slot (matches the seed's linear implementation —
            // a slot *boundary*, including a slot's own start, qualifies,
            // so only slots beginning strictly before `not_before` can
            // push the answer back).
            return match self.slots.range(..not_before).next_back() {
                Some((_, slot)) if slot.window.end.0 > nb => slot.window.end,
                _ => not_before,
            };
        }
        // 1. The gap containing `not_before`, if any.
        if let Some((&gs, &ge)) = self.gaps.range(..=nb).next_back() {
            debug_assert!(gs <= nb);
            if ge > nb && ge - nb >= need {
                return not_before;
            }
        }
        // 2. The earliest gap strictly after `not_before` that fits.
        // Buckets above the ambiguous one are guaranteed fits, so their
        // first in-range entry is their best candidate.
        let min_class = len_class(need);
        let mut best = u64::MAX;
        for class in (min_class + 1)..64 {
            if let Some(&gs) = self.gaps_by_len[class].range(nb + 1..).next() {
                best = best.min(gs);
            }
        }
        // The ambiguous bucket holds lengths in [2^min_class, 2^(min_class+1));
        // check candidates until one fits or they can no longer improve.
        for &gs in self.gaps_by_len[min_class].range(nb + 1..) {
            if gs >= best {
                break;
            }
            let ge = self.gaps[&gs];
            if ge - gs >= need {
                best = gs;
                break;
            }
        }
        debug_assert!(best < u64::MAX, "the trailing infinite gap always fits");
        SimTime(best)
    }

    /// Reserve `[start, start+dur)`. Fails on any overlap, and on
    /// zero-length requests (a zero-length slot reserves nothing).
    pub fn reserve(
        &mut self,
        start: SimTime,
        dur: SimDuration,
        kind: SlotKind,
        owner: TaskId,
    ) -> Result<Window> {
        if dur == SimDuration::ZERO {
            return Err(Error::Allocation(format!(
                "zero-duration link slot at {start:?} reserves nothing"
            )));
        }
        let window = Window::from_duration(start, dur);
        let (s, e) = (window.start.0, window.end.0);
        match self.gaps.range(..=s).next_back().map(|(&gs, &ge)| (gs, ge)) {
            Some((gs, ge)) if ge >= e => {
                // The gap [gs, ge) contains [s, e): split it around the slot.
                self.gap_remove(gs);
                if gs < s {
                    self.gap_insert(gs, s);
                }
                if e < ge {
                    self.gap_insert(e, ge);
                }
                self.slots.insert(window.start, Slot { window, kind, owner });
                self.by_owner.entry(owner).or_default().push(window.start);
                Ok(window)
            }
            _ => {
                let conflict = self
                    .slots
                    .range(..window.end)
                    .next_back()
                    .map(|(_, slot)| slot.window);
                Err(Error::Allocation(format!(
                    "link slot {window:?} overlaps existing {conflict:?}"
                )))
            }
        }
    }

    /// Convenience: earliest-fit then reserve. Returns the reserved window.
    pub fn reserve_earliest(
        &mut self,
        not_before: SimTime,
        dur: SimDuration,
        kind: SlotKind,
        owner: TaskId,
    ) -> Window {
        let start = self.earliest_fit(not_before, dur);
        self.reserve(start, dur, kind, owner)
            .expect("earliest_fit returned an occupied window")
    }

    /// Remove all slots owned by `task`; returns how many were removed.
    pub fn remove_owner(&mut self, task: TaskId) -> usize {
        let starts = self.by_owner.remove(&task).unwrap_or_default();
        for &s in &starts {
            self.remove_slot(s);
        }
        starts.len()
    }

    /// Remove exactly the slot starting at `start` if it belongs to
    /// `owner`; returns whether a slot was removed. The precise
    /// counterpart of [`Timeline::remove_owner_from`] for rolling back one
    /// known reservation without touching the owner's other slots (the
    /// planning layer's tentative-attempt rollback).
    pub fn release(&mut self, start: SimTime, owner: TaskId) -> bool {
        match self.slots.get(&start) {
            Some(slot) if slot.owner == owner => {}
            _ => return false,
        }
        self.remove_slot(start);
        self.forget_owner_start(owner, start);
        true
    }

    /// Drop `start` from `owner`'s index entry, removing the entry when it
    /// becomes empty — the single home of the by-owner bookkeeping shared
    /// by [`Timeline::release`] and [`Timeline::prune_before`].
    fn forget_owner_start(&mut self, owner: TaskId, start: SimTime) {
        let mut now_empty = false;
        if let Some(starts) = self.by_owner.get_mut(&owner) {
            if let Some(pos) = starts.iter().position(|&s| s == start) {
                starts.swap_remove(pos);
            }
            now_empty = starts.is_empty();
        }
        if now_empty {
            self.by_owner.remove(&owner);
        }
    }

    /// Remove slots owned by `task` that start at or after `t` (keep already
    /// transmitted messages when cancelling a future allocation).
    pub fn remove_owner_from(&mut self, task: TaskId, t: SimTime) -> usize {
        let mut removed = Vec::new();
        let mut now_empty = false;
        if let Some(starts) = self.by_owner.get_mut(&task) {
            starts.retain(|&s| {
                if s >= t {
                    removed.push(s);
                    false
                } else {
                    true
                }
            });
            now_empty = starts.is_empty();
        }
        if now_empty {
            self.by_owner.remove(&task);
        }
        for &s in &removed {
            self.remove_slot(s);
        }
        removed.len()
    }

    /// Drop slots that ended at or before `t` (bookkeeping compaction).
    pub fn prune_before(&mut self, t: SimTime) -> usize {
        let mut n = 0;
        loop {
            let (start, owner) = match self.slots.first_key_value() {
                Some((&start, slot)) if slot.window.end <= t => (start, slot.owner),
                _ => break,
            };
            self.remove_slot(start);
            self.forget_owner_start(owner, start);
            n += 1;
        }
        n
    }

    /// Read-only probe: is `window` entirely free (no overlapping slot)?
    ///
    /// Answered from the gap index in O(log n): the window is free exactly
    /// when one recorded gap contains it. Zero-length windows are free at
    /// any slot boundary (consistent with the `earliest_fit` degenerate
    /// case). The planning layer uses this to assert staged reservations
    /// land where `earliest_fit` pointed, without a mutable borrow.
    pub fn is_free(&self, window: &Window) -> bool {
        let (s, e) = (window.start.0, window.end.0);
        if s == e {
            return match self.slots.range(..window.start).next_back() {
                Some((_, slot)) => slot.window.end.0 <= s,
                None => true,
            };
        }
        match self.gaps.range(..=s).next_back() {
            Some((&gs, &ge)) => gs <= s && e <= ge,
            None => false,
        }
    }

    /// All slots overlapping `window`, in start order.
    pub fn overlapping<'a>(&'a self, window: &'a Window) -> impl Iterator<Item = &'a Slot> {
        // The slot that begins at or before the window may still overlap it;
        // everything else relevant begins inside the window.
        let begin = match self.slots.range(..=window.start).next_back() {
            Some((&s, slot)) if slot.window.end > window.start => s,
            _ => window.start,
        };
        let end = window.end;
        self.slots
            .range(begin..)
            .take_while(move |(&s, _)| s < end)
            .map(|(_, slot)| slot)
            .filter(move |slot| slot.window.overlaps(window))
    }

    /// All slots in start order.
    ///
    /// Materialised into a fresh `Vec`: the calendar is gap-indexed rather
    /// than a flat vector. Intended for tests and diagnostics, not hot
    /// paths.
    pub fn slots(&self) -> Vec<Slot> {
        self.slots.values().cloned().collect()
    }

    /// All slots in start order, borrowed straight from the calendar — the
    /// allocation-free counterpart of [`Timeline::slots`] for paths
    /// (fingerprints, invariant sweeps) that only walk the reservations.
    pub fn slots_iter(&self) -> impl Iterator<Item = &Slot> {
        self.slots.values()
    }

    /// The slot starting exactly at `start`, if any. O(log n); the
    /// planning layer snapshots a reservation here before releasing it so
    /// the release can be rolled back precisely.
    pub fn slot_at(&self, start: SimTime) -> Option<&Slot> {
        self.slots.get(&start)
    }

    /// Snapshots of every slot `owner` holds that starts at or after `t`,
    /// in start order — exactly the set [`Timeline::remove_owner_from`]
    /// would remove. The planning layer captures these before staging an
    /// eviction so the eviction can be rolled back.
    pub fn owner_slots_from(&self, owner: TaskId, t: SimTime) -> Vec<Slot> {
        let mut out = Vec::new();
        self.owner_slots_from_into(owner, t, &mut out);
        out
    }

    /// [`Timeline::owner_slots_from`] into a caller-supplied buffer: clears
    /// `out`, then appends the snapshots in start order. Lets the planning
    /// layer reuse one scratch `Vec` across eviction stagings instead of
    /// allocating per victim.
    pub fn owner_slots_from_into(&self, owner: TaskId, t: SimTime, out: &mut Vec<Slot>) {
        out.clear();
        if let Some(starts) = self.by_owner.get(&owner) {
            out.extend(
                starts
                    .iter()
                    .filter(|&&s| s >= t)
                    .map(|s| self.slots[s].clone()),
            );
        }
        out.sort_by_key(|s| s.window.start);
    }

    /// True when both calendars hold exactly the same reservations
    /// (slot-by-slot; the derived gap/owner indices are determined by the
    /// slots). Debug instrumentation for the scratch-timeline pool.
    pub fn same_reservations(&self, other: &Timeline) -> bool {
        self.slots == other.slots
    }

    /// Total reserved time within `window`.
    pub fn busy_time_in(&self, window: &Window) -> SimDuration {
        let mut total = SimDuration::ZERO;
        for s in self.overlapping(window) {
            let lo = s.window.start.max(window.start);
            let hi = s.window.end.min(window.end);
            total = total + hi.since(lo);
        }
        total
    }

    /// Debug invariant: slots sorted and non-overlapping, and every index
    /// (gaps, length buckets, owner map) exactly consistent with them.
    pub fn check_invariants(&self) -> Result<()> {
        // Slots: keyed by their own start, non-overlapping, non-empty.
        let mut cursor = 0u64;
        let mut checked_gaps = 0usize;
        for (key, slot) in &self.slots {
            if *key != slot.window.start {
                return Err(Error::Invariant(format!(
                    "slot keyed at {key:?} but starts at {:?}",
                    slot.window.start
                )));
            }
            if slot.window.end <= slot.window.start {
                return Err(Error::Invariant(format!("empty slot {:?}", slot.window)));
            }
            let (s, e) = (slot.window.start.0, slot.window.end.0);
            if s < cursor {
                return Err(Error::Invariant(format!(
                    "timeline overlap: slot {:?} begins before {cursor}",
                    slot.window
                )));
            }
            // The complement between `cursor` and this slot must be exactly
            // one recorded gap (or nothing, when the slots touch).
            if s > cursor {
                if self.gaps.get(&cursor) != Some(&s) {
                    return Err(Error::Invariant(format!(
                        "missing/incorrect gap [{cursor}, {s})"
                    )));
                }
                checked_gaps += 1;
            }
            cursor = e;
        }
        if self.gaps.get(&cursor) != Some(&u64::MAX) {
            return Err(Error::Invariant(format!(
                "missing trailing gap [{cursor}, MAX)"
            )));
        }
        checked_gaps += 1;
        if checked_gaps != self.gaps.len() {
            return Err(Error::Invariant(format!(
                "stray gaps: {} recorded, {checked_gaps} expected",
                self.gaps.len()
            )));
        }
        // Length buckets mirror the gap map exactly.
        let bucketed: usize = self.gaps_by_len.iter().map(BTreeSet::len).sum();
        if bucketed != self.gaps.len() {
            return Err(Error::Invariant(format!(
                "length buckets hold {bucketed} gaps, map holds {}",
                self.gaps.len()
            )));
        }
        for (&gs, &ge) in &self.gaps {
            if !self.gaps_by_len[len_class(ge - gs)].contains(&gs) {
                return Err(Error::Invariant(format!(
                    "gap [{gs}, {ge}) missing from its length bucket"
                )));
            }
        }
        // Owner index: every entry names a live slot of that owner, and
        // every slot is indexed exactly once.
        let indexed: usize = self.by_owner.values().map(Vec::len).sum();
        if indexed != self.slots.len() {
            return Err(Error::Invariant(format!(
                "owner index holds {indexed} starts, calendar holds {}",
                self.slots.len()
            )));
        }
        for (owner, starts) in &self.by_owner {
            for s in starts {
                match self.slots.get(s) {
                    Some(slot) if slot.owner == *owner => {}
                    _ => {
                        return Err(Error::Invariant(format!(
                            "owner index entry {owner:?}@{s:?} has no matching slot"
                        )))
                    }
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }
    fn d(ms: u64) -> SimDuration {
        SimDuration::from_millis(ms)
    }

    #[test]
    fn empty_timeline_fits_immediately() {
        let tl = Timeline::new();
        assert_eq!(tl.earliest_fit(t(5), d(10)), t(5));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn earliest_fit_skips_occupied() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(30), d(10), SlotKind::StateUpdate, TaskId(1)).unwrap();
        // Fits in the gap [20, 30).
        assert_eq!(tl.earliest_fit(t(0), d(10)), t(0));
        assert_eq!(tl.earliest_fit(t(5), d(10)), t(20));
        // Too big for the gap: lands after the last slot.
        assert_eq!(tl.earliest_fit(t(5), d(11)), t(40));
        // Start inside a slot: pushed to its end.
        assert_eq!(tl.earliest_fit(t(12), d(5)), t(20));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn reserve_rejects_overlap() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        assert!(tl.reserve(t(15), d(10), SlotKind::HpAllocMsg, TaskId(2)).is_err());
        assert!(tl.reserve(t(5), d(6), SlotKind::HpAllocMsg, TaskId(2)).is_err());
        // Touching is fine (half-open).
        assert!(tl.reserve(t(20), d(5), SlotKind::HpAllocMsg, TaskId(2)).is_ok());
        assert!(tl.reserve(t(5), d(5), SlotKind::HpAllocMsg, TaskId(3)).is_ok());
        tl.check_invariants().unwrap();
    }

    #[test]
    fn reserve_rejects_zero_duration() {
        let mut tl = Timeline::new();
        assert!(tl.reserve(t(5), SimDuration::ZERO, SlotKind::PollMsg, TaskId(1)).is_err());
        assert_eq!(tl.len(), 0);
        tl.check_invariants().unwrap();
    }

    #[test]
    fn earliest_fit_zero_duration_matches_linear_semantics() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        assert_eq!(tl.earliest_fit(t(5), SimDuration::ZERO), t(5));
        // A slot's own start is a boundary, not "inside" — the seed's scan
        // returns it unchanged.
        assert_eq!(tl.earliest_fit(t(10), SimDuration::ZERO), t(10));
        assert_eq!(tl.earliest_fit(t(12), SimDuration::ZERO), t(20));
        assert_eq!(tl.earliest_fit(t(20), SimDuration::ZERO), t(20));
    }

    #[test]
    fn reserve_earliest_composes() {
        let mut tl = Timeline::new();
        let w1 = tl.reserve_earliest(t(0), d(10), SlotKind::LpAllocMsg, TaskId(1));
        let w2 = tl.reserve_earliest(t(0), d(10), SlotKind::LpAllocMsg, TaskId(2));
        assert_eq!(w1.start, t(0));
        assert_eq!(w2.start, t(10));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn remove_owner_clears_slots() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::StateUpdate, TaskId(1)).unwrap();
        tl.reserve(t(20), d(5), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        assert_eq!(tl.remove_owner(TaskId(1)), 2);
        assert_eq!(tl.len(), 1);
        // Freed space is reusable.
        assert_eq!(tl.earliest_fit(t(0), d(5)), t(0));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn release_removes_exactly_one_owned_slot() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::LpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::PreemptMsg, TaskId(1)).unwrap();
        tl.reserve(t(20), d(5), SlotKind::LpAllocMsg, TaskId(2)).unwrap();
        // Wrong owner / empty start: refused, nothing changes.
        assert!(!tl.release(t(0), TaskId(2)));
        assert!(!tl.release(t(7), TaskId(1)));
        assert_eq!(tl.len(), 3);
        // Exact removal leaves the owner's other slots alone.
        assert!(tl.release(t(0), TaskId(1)));
        assert_eq!(tl.len(), 2);
        assert_eq!(tl.slots()[0].window.start, t(10), "sibling slot survives");
        assert_eq!(tl.earliest_fit(t(0), d(5)), t(0), "freed space is reusable");
        tl.check_invariants().unwrap();
        assert!(tl.release(t(10), TaskId(1)));
        assert!(!tl.release(t(10), TaskId(1)), "second release is a no-op");
        tl.check_invariants().unwrap();
    }

    #[test]
    fn remove_owner_from_keeps_past() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::InputTransfer, TaskId(1)).unwrap();
        assert_eq!(tl.remove_owner_from(TaskId(1), t(8)), 1);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.slots()[0].window.start, t(0));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn is_free_matches_overlap_semantics() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        assert!(tl.is_free(&Window::new(t(0), t(10))), "touching is free (half-open)");
        assert!(tl.is_free(&Window::new(t(20), t(25))));
        assert!(!tl.is_free(&Window::new(t(5), t(11))));
        assert!(!tl.is_free(&Window::new(t(12), t(15))));
        assert!(!tl.is_free(&Window::new(t(19), t(30))));
        // Zero-length windows: free at boundaries, not strictly inside.
        assert!(tl.is_free(&Window::new(t(10), t(10))));
        assert!(tl.is_free(&Window::new(t(20), t(20))));
        assert!(!tl.is_free(&Window::new(t(15), t(15))));
        // Agreement with the gap-driven earliest_fit on random probes.
        for start in 0..30u64 {
            for dur in 1..12u64 {
                let free = tl.is_free(&Window::new(t(start), t(start + dur)));
                let fit = tl.earliest_fit(t(start), d(dur)) == t(start);
                assert_eq!(free, fit, "start={start} dur={dur}");
            }
        }
    }

    #[test]
    fn overlapping_iterates_correctly() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(20), d(10), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        tl.reserve(t(40), d(10), SlotKind::HpAllocMsg, TaskId(3)).unwrap();
        let window = Window::new(t(5), t(45));
        let owners: Vec<_> = tl.overlapping(&window).map(|s| s.owner).collect();
        assert_eq!(owners, vec![TaskId(1), TaskId(2), TaskId(3)]);
        let window = Window::new(t(10), t(20));
        assert_eq!(tl.overlapping(&window).count(), 0, "touching doesn't overlap");
    }

    #[test]
    fn busy_time_clips_to_window() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(20), d(10), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        let w = Window::new(t(5), t(25));
        assert_eq!(tl.busy_time_in(&w), d(10)); // 5 from first + 5 from second
    }

    #[test]
    fn prune_drops_history() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        assert_eq!(tl.prune_before(t(9)), 1);
        assert_eq!(tl.len(), 1);
        tl.check_invariants().unwrap();
    }

    #[test]
    fn gaps_coalesce_on_release() {
        let mut tl = Timeline::new();
        // Three adjacent slots; removing the middle one must merge its
        // window with nothing (neighbours reserved), removing the rest must
        // coalesce back to the single infinite gap.
        tl.reserve(t(0), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        tl.reserve(t(20), d(10), SlotKind::HpAllocMsg, TaskId(3)).unwrap();
        tl.check_invariants().unwrap();
        assert_eq!(tl.remove_owner(TaskId(2)), 1);
        tl.check_invariants().unwrap();
        // The freed middle is immediately reusable.
        assert_eq!(tl.earliest_fit(t(0), d(10)), t(10));
        assert_eq!(tl.remove_owner(TaskId(1)), 1);
        tl.check_invariants().unwrap();
        assert_eq!(tl.remove_owner(TaskId(3)), 1);
        tl.check_invariants().unwrap();
        assert!(tl.is_empty());
        assert_eq!(tl.earliest_fit(t(0), d(1)), t(0));
    }

    #[test]
    fn dense_calendar_fit_is_fast_path_correct() {
        // 1 ms slots with 1 ms gaps: a request that outgrows every interior
        // gap must land after the last slot (the seed bench's worst case).
        let mut tl = Timeline::new();
        for i in 0..1_000u64 {
            tl.reserve(
                SimTime::from_micros(2_000 * i),
                SimDuration::from_millis(1),
                SlotKind::StateUpdate,
                TaskId(i),
            )
            .unwrap();
        }
        assert_eq!(
            tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(1_500)),
            SimTime::from_micros(2_000 * 999 + 1_000),
        );
        // A request that fits an interior gap takes the first one.
        assert_eq!(
            tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(900)),
            SimTime::from_micros(1_000),
        );
        tl.check_invariants().unwrap();
    }
}
