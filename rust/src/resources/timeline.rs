//! Exclusive-resource reservation timeline (the shared wireless link).
//!
//! Variable-length, non-overlapping, half-open slots kept sorted by start
//! time. The controller reserves one slot per message: allocation messages,
//! input transfers, state updates, preemption notices (§3.1).

use crate::error::{Error, Result};
use crate::task::{TaskId, Window};
use crate::time::{SimDuration, SimTime};

/// What a link slot carries (sizes differ per kind — see `net`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SlotKind {
    /// Controller → device: high-priority allocation decision.
    HpAllocMsg,
    /// Controller → device: low-priority allocation decision.
    LpAllocMsg,
    /// Device → device: input image transfer for an offloaded task.
    InputTransfer,
    /// Device → controller: status update on task completion.
    StateUpdate,
    /// Controller → device: preemption notice.
    PreemptMsg,
    /// Workstealer poll: "do you have work?" (decentralised baseline).
    PollMsg,
}

/// One reserved slot.
#[derive(Debug, Clone)]
pub struct Slot {
    pub window: Window,
    pub kind: SlotKind,
    /// The task this slot serves.
    pub owner: TaskId,
}

/// A sorted, non-overlapping reservation calendar for an exclusive resource.
#[derive(Debug, Clone, Default)]
pub struct Timeline {
    /// Sorted by `window.start`; pairwise non-overlapping.
    slots: Vec<Slot>,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline { slots: Vec::new() }
    }

    /// Number of reserved slots.
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// Index of the first slot whose end is after `t` (binary search).
    fn first_ending_after(&self, t: SimTime) -> usize {
        // Slots are non-overlapping and sorted by start, hence also by end.
        self.slots.partition_point(|s| s.window.end <= t)
    }

    /// Earliest start `>= not_before` where a slot of `dur` fits.
    ///
    /// Linear scan over the gaps from the first relevant slot; the paper's
    /// own complexity analysis is linear in allocated tasks (§6.3).
    pub fn earliest_fit(&self, not_before: SimTime, dur: SimDuration) -> SimTime {
        let mut candidate = not_before;
        for slot in &self.slots[self.first_ending_after(not_before)..] {
            let needed_end = candidate + dur;
            if needed_end <= slot.window.start {
                return candidate;
            }
            candidate = candidate.max(slot.window.end);
        }
        candidate
    }

    /// Reserve `[start, start+dur)`. Fails on any overlap.
    pub fn reserve(
        &mut self,
        start: SimTime,
        dur: SimDuration,
        kind: SlotKind,
        owner: TaskId,
    ) -> Result<Window> {
        let window = Window::from_duration(start, dur);
        let idx = self.slots.partition_point(|s| s.window.start < window.start);
        // Check neighbour on each side (sufficient because non-overlapping).
        if idx > 0 && self.slots[idx - 1].window.overlaps(&window) {
            return Err(Error::Allocation(format!(
                "link slot {:?} overlaps existing {:?}",
                window, self.slots[idx - 1].window
            )));
        }
        if idx < self.slots.len() && self.slots[idx].window.overlaps(&window) {
            return Err(Error::Allocation(format!(
                "link slot {:?} overlaps existing {:?}",
                window, self.slots[idx].window
            )));
        }
        self.slots.insert(idx, Slot { window, kind, owner });
        Ok(window)
    }

    /// Convenience: earliest-fit then reserve. Returns the reserved window.
    pub fn reserve_earliest(
        &mut self,
        not_before: SimTime,
        dur: SimDuration,
        kind: SlotKind,
        owner: TaskId,
    ) -> Window {
        let start = self.earliest_fit(not_before, dur);
        self.reserve(start, dur, kind, owner)
            .expect("earliest_fit returned an occupied window")
    }

    /// Remove all slots owned by `task`; returns how many were removed.
    pub fn remove_owner(&mut self, task: TaskId) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.owner != task);
        before - self.slots.len()
    }

    /// Remove slots owned by `task` that start at or after `t` (keep already
    /// transmitted messages when cancelling a future allocation).
    pub fn remove_owner_from(&mut self, task: TaskId, t: SimTime) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.owner != task || s.window.start < t);
        before - self.slots.len()
    }

    /// Drop slots that ended at or before `t` (bookkeeping compaction).
    pub fn prune_before(&mut self, t: SimTime) -> usize {
        let cut = self.first_ending_after(t);
        self.slots.drain(..cut).count()
    }

    /// All slots overlapping `window`.
    pub fn overlapping<'a>(&'a self, window: &'a Window) -> impl Iterator<Item = &'a Slot> {
        let start = self.first_ending_after(window.start);
        self.slots[start..]
            .iter()
            .take_while(move |s| s.window.start < window.end)
            .filter(move |s| s.window.overlaps(window))
    }

    /// Iterate all slots (sorted).
    pub fn slots(&self) -> &[Slot] {
        &self.slots
    }

    /// Total reserved time within `window`.
    pub fn busy_time_in(&self, window: &Window) -> SimDuration {
        let mut total = SimDuration::ZERO;
        for s in self.overlapping(window) {
            let lo = s.window.start.max(window.start);
            let hi = s.window.end.min(window.end);
            total = total + hi.since(lo);
        }
        total
    }

    /// Debug invariant: sorted and non-overlapping.
    pub fn check_invariants(&self) -> Result<()> {
        for pair in self.slots.windows(2) {
            if pair[0].window.start > pair[1].window.start {
                return Err(Error::Invariant("timeline not sorted".into()));
            }
            if pair[0].window.overlaps(&pair[1].window) {
                return Err(Error::Invariant(format!(
                    "timeline overlap: {:?} vs {:?}",
                    pair[0].window, pair[1].window
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t(ms: u64) -> SimTime {
        SimTime::from_millis(ms)
    }
    fn d(ms: u64) -> SimDuration {
        SimDuration::from_millis(ms)
    }

    #[test]
    fn empty_timeline_fits_immediately() {
        let tl = Timeline::new();
        assert_eq!(tl.earliest_fit(t(5), d(10)), t(5));
    }

    #[test]
    fn earliest_fit_skips_occupied() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(30), d(10), SlotKind::StateUpdate, TaskId(1)).unwrap();
        // Fits in the gap [20, 30).
        assert_eq!(tl.earliest_fit(t(0), d(10)), t(0));
        assert_eq!(tl.earliest_fit(t(5), d(10)), t(20));
        // Too big for the gap: lands after the last slot.
        assert_eq!(tl.earliest_fit(t(5), d(11)), t(40));
        // Start inside a slot: pushed to its end.
        assert_eq!(tl.earliest_fit(t(12), d(5)), t(20));
    }

    #[test]
    fn reserve_rejects_overlap() {
        let mut tl = Timeline::new();
        tl.reserve(t(10), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        assert!(tl.reserve(t(15), d(10), SlotKind::HpAllocMsg, TaskId(2)).is_err());
        assert!(tl.reserve(t(5), d(6), SlotKind::HpAllocMsg, TaskId(2)).is_err());
        // Touching is fine (half-open).
        assert!(tl.reserve(t(20), d(5), SlotKind::HpAllocMsg, TaskId(2)).is_ok());
        assert!(tl.reserve(t(5), d(5), SlotKind::HpAllocMsg, TaskId(3)).is_ok());
        tl.check_invariants().unwrap();
    }

    #[test]
    fn reserve_earliest_composes() {
        let mut tl = Timeline::new();
        let w1 = tl.reserve_earliest(t(0), d(10), SlotKind::LpAllocMsg, TaskId(1));
        let w2 = tl.reserve_earliest(t(0), d(10), SlotKind::LpAllocMsg, TaskId(2));
        assert_eq!(w1.start, t(0));
        assert_eq!(w2.start, t(10));
        tl.check_invariants().unwrap();
    }

    #[test]
    fn remove_owner_clears_slots() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::StateUpdate, TaskId(1)).unwrap();
        tl.reserve(t(20), d(5), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        assert_eq!(tl.remove_owner(TaskId(1)), 2);
        assert_eq!(tl.len(), 1);
        // Freed space is reusable.
        assert_eq!(tl.earliest_fit(t(0), d(5)), t(0));
    }

    #[test]
    fn remove_owner_from_keeps_past() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::InputTransfer, TaskId(1)).unwrap();
        assert_eq!(tl.remove_owner_from(TaskId(1), t(8)), 1);
        assert_eq!(tl.len(), 1);
        assert_eq!(tl.slots()[0].window.start, t(0));
    }

    #[test]
    fn overlapping_iterates_correctly() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(20), d(10), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        tl.reserve(t(40), d(10), SlotKind::HpAllocMsg, TaskId(3)).unwrap();
        let window = Window::new(t(5), t(45));
        let owners: Vec<_> = tl.overlapping(&window).map(|s| s.owner).collect();
        assert_eq!(owners, vec![TaskId(1), TaskId(2), TaskId(3)]);
        let window = Window::new(t(10), t(20));
        assert_eq!(tl.overlapping(&window).count(), 0, "touching doesn't overlap");
    }

    #[test]
    fn busy_time_clips_to_window() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(10), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(20), d(10), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        let w = Window::new(t(5), t(25));
        assert_eq!(tl.busy_time_in(&w), d(10)); // 5 from first + 5 from second
    }

    #[test]
    fn prune_drops_history() {
        let mut tl = Timeline::new();
        tl.reserve(t(0), d(5), SlotKind::HpAllocMsg, TaskId(1)).unwrap();
        tl.reserve(t(10), d(5), SlotKind::HpAllocMsg, TaskId(2)).unwrap();
        assert_eq!(tl.prune_before(t(9)), 1);
        assert_eq!(tl.len(), 1);
    }
}
