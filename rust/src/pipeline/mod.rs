//! Frame pipeline lifecycle (§3, §5).
//!
//! Devices sample their conveyor belt every `frame_period_s`; the paper
//! starts devices "as pairs in a staggered fashion ... two checking at the
//! start of the cycle and the other two at middle cycle", with "a random
//! offset between any two devices at the start of a frame".

use crate::config::SystemConfig;
use crate::task::{DeviceId, FrameId, RequestId, TaskId};
use crate::time::{SimDuration, SimTime};
use crate::trace::FrameLoad;
use crate::util::rng::Rng;

/// Per-device start offsets implementing staggered pairs + random jitter.
#[derive(Debug, Clone)]
pub struct StartSchedule {
    offsets: Vec<SimDuration>,
    period: SimDuration,
}

impl StartSchedule {
    /// Draw the per-device offsets for the configured topology.
    pub fn sample(cfg: &SystemConfig, rng: &mut Rng) -> StartSchedule {
        let period = SimDuration::from_secs_f64(cfg.frame_period_s);
        let offsets = (0..cfg.devices)
            .map(|d| {
                let pair_shift = if cfg.staggered_pairs && d >= cfg.devices / 2 {
                    // Second pair samples at mid-cycle.
                    SimDuration::from_secs_f64(cfg.frame_period_s / 2.0)
                } else {
                    SimDuration::ZERO
                };
                let jitter =
                    SimDuration::from_secs_f64(rng.range_f64(0.0, cfg.max_start_offset_s));
                pair_shift + jitter
            })
            .collect();
        StartSchedule { offsets, period }
    }

    /// Start time of `cycle` on `device`.
    pub fn frame_start(&self, device: DeviceId, cycle: usize) -> SimTime {
        SimTime::ZERO + self.offsets[device.0 as usize] + self.period * cycle as u64
    }

    /// The frame pipeline period.
    pub fn period(&self) -> SimDuration {
        self.period
    }
}

/// Lifecycle status of one frame.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FrameStatus {
    /// Pipeline still in flight.
    InFlight,
    /// Every stage the frame required completed before its deadline.
    Completed,
    /// Some stage failed (annotated with which).
    Failed(FrameFailure),
}

/// Which stage sank the frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameFailure {
    /// Stage-2 high-priority task was never allocated or was violated.
    HighPriority,
    /// Stage-3: at least one DNN task of the set failed.
    LowPrioritySet,
}

/// Bookkeeping for one frame's walk through the pipeline.
#[derive(Debug, Clone)]
pub struct FrameRecord {
    /// Unique frame id.
    pub id: FrameId,
    /// Device whose conveyor belt sampled the frame.
    pub device: DeviceId,
    /// Cycle index within the trace.
    pub cycle: usize,
    /// The trace workload of this frame.
    pub load: FrameLoad,
    /// When the device sampled the frame.
    pub start: SimTime,
    /// The pipeline deadline: everything must finish within the period.
    pub deadline: SimTime,
    /// The stage-2 task, once spawned.
    pub hp_task: Option<TaskId>,
    /// The stage-3 request, once spawned.
    pub lp_request: Option<RequestId>,
    /// Low-priority tasks still outstanding.
    pub lp_remaining: u32,
    /// Current lifecycle status.
    pub status: FrameStatus,
}

impl FrameRecord {
    /// A fresh record for one sampled frame.
    pub fn new(
        id: FrameId,
        device: DeviceId,
        cycle: usize,
        load: FrameLoad,
        start: SimTime,
        period: SimDuration,
    ) -> FrameRecord {
        let status = if load.spawns_hp() {
            FrameStatus::InFlight
        } else {
            // No object: the pipeline is the stage-1 detector only, which
            // always runs locally — the frame is trivially complete.
            FrameStatus::Completed
        };
        FrameRecord {
            id,
            device,
            cycle,
            load,
            start,
            deadline: start + period,
            hp_task: None,
            lp_request: None,
            lp_remaining: load.lp_tasks() as u32,
            status,
        }
    }

    /// Stage-2 outcome.
    pub fn on_hp_result(&mut self, completed: bool) {
        if self.status != FrameStatus::InFlight {
            return;
        }
        if !completed {
            self.status = FrameStatus::Failed(FrameFailure::HighPriority);
        } else if self.load.lp_tasks() == 0 {
            self.status = FrameStatus::Completed;
        }
        // Otherwise stay in flight until the LP set resolves.
    }

    /// One stage-3 task of the set resolved.
    pub fn on_lp_result(&mut self, completed: bool) {
        if self.status != FrameStatus::InFlight {
            return;
        }
        if !completed {
            self.status = FrameStatus::Failed(FrameFailure::LowPrioritySet);
            return;
        }
        assert!(self.lp_remaining > 0, "more LP results than tasks");
        self.lp_remaining -= 1;
        if self.lp_remaining == 0 {
            self.status = FrameStatus::Completed;
        }
    }

    /// Did every stage the frame required complete in time?
    pub fn completed(&self) -> bool {
        self.status == FrameStatus::Completed
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> SystemConfig {
        SystemConfig::default()
    }

    #[test]
    fn staggered_pairs_offset_by_half_period() {
        let c = cfg();
        let mut rng = Rng::seed_from_u64(1);
        let s = StartSchedule::sample(&c, &mut rng);
        let early = s.frame_start(DeviceId(0), 0);
        let late = s.frame_start(DeviceId(2), 0);
        let half = c.frame_period_s / 2.0;
        let gap = late.since(early).as_secs_f64();
        // Half-period shift ± the random jitter of both devices.
        assert!(
            (gap - half).abs() <= c.max_start_offset_s,
            "gap {gap} vs half {half}"
        );
    }

    #[test]
    fn cycles_advance_by_period() {
        let c = cfg();
        let mut rng = Rng::seed_from_u64(2);
        let s = StartSchedule::sample(&c, &mut rng);
        let a = s.frame_start(DeviceId(1), 0);
        let b = s.frame_start(DeviceId(1), 5);
        assert_eq!(
            b.since(a),
            SimDuration::from_secs_f64(c.frame_period_s) * 5
        );
    }

    #[test]
    fn offsets_are_random_but_bounded() {
        let c = cfg();
        let mut rng = Rng::seed_from_u64(3);
        let s = StartSchedule::sample(&c, &mut rng);
        let a = s.frame_start(DeviceId(0), 0);
        let b = s.frame_start(DeviceId(1), 0);
        assert_ne!(a, b, "random offsets should differ");
        assert!(a.as_secs_f64() <= c.max_start_offset_s);
    }

    fn frame(load: FrameLoad) -> FrameRecord {
        FrameRecord::new(
            FrameId(1),
            DeviceId(0),
            0,
            load,
            SimTime::ZERO,
            SimDuration::from_secs_f64(18.86),
        )
    }

    #[test]
    fn no_object_frames_complete_trivially() {
        let f = frame(FrameLoad::NoObject);
        assert!(f.completed());
    }

    #[test]
    fn hp_only_frame_completes_on_hp() {
        let mut f = frame(FrameLoad::HpOnly);
        assert_eq!(f.status, FrameStatus::InFlight);
        f.on_hp_result(true);
        assert!(f.completed());
    }

    #[test]
    fn hp_failure_fails_frame() {
        let mut f = frame(FrameLoad::HpAndLp(3));
        f.on_hp_result(false);
        assert_eq!(f.status, FrameStatus::Failed(FrameFailure::HighPriority));
        // Late LP results cannot resurrect it.
        f.on_lp_result(true);
        assert_eq!(f.status, FrameStatus::Failed(FrameFailure::HighPriority));
    }

    #[test]
    fn full_set_required_for_completion() {
        let mut f = frame(FrameLoad::HpAndLp(3));
        f.on_hp_result(true);
        assert_eq!(f.status, FrameStatus::InFlight);
        f.on_lp_result(true);
        f.on_lp_result(true);
        assert_eq!(f.status, FrameStatus::InFlight);
        f.on_lp_result(true);
        assert!(f.completed());
    }

    #[test]
    fn one_lp_failure_sinks_the_set() {
        let mut f = frame(FrameLoad::HpAndLp(2));
        f.on_hp_result(true);
        f.on_lp_result(true);
        f.on_lp_result(false);
        assert_eq!(f.status, FrameStatus::Failed(FrameFailure::LowPrioritySet));
    }

    #[test]
    fn deadline_is_one_period() {
        let f = frame(FrameLoad::HpOnly);
        assert_eq!(f.deadline, SimTime::from_secs_f64(18.86));
    }
}
