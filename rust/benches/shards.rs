//! Sharded-control-plane benchmarks: the same 1024-device decision load
//! at growing shard counts.
//!
//! Two claims are tracked across commits in `BENCH_shards.json`:
//!
//! * **Per-decision scheduling cost drops with shards.** Each shard owns
//!   its own link-calendar partition and only its own devices' occupancy,
//!   so one low-priority admission on a loaded plane touches a K-times
//!   smaller calendar (`admit_after_sweep/*`).
//! * **The end-to-end decision sweep parallelises.** Shards share no
//!   mutable state, so a batch decision phase runs one shard per OS
//!   thread (`std::thread::scope`); the 8-shard parallel sweep must beat
//!   the single-shard serial sweep (`sweep_parallel/*` vs
//!   `sweep_serial/shards=1`) — the first real wall-clock parallelism in
//!   the codebase.
//! * **The batched engine's sweep door scales too.** The parallel event
//!   loop (`sharding.engine = parallel`) reaches shards through
//!   `ControlSurface::{hp_sweep, lp_request_sweep}`; the
//!   `surface_hp_sweep/*` and `surface_lp_sweep/*` rows time those exact
//!   entry points on the 1024-device fixture so the engine's batch cost
//!   is tracked at every shard count.

use pats::bench::{bench_with_setup, section, write_json, BenchResult};
use pats::config::SystemConfig;
use pats::coordinator::{ControlSurface, HpSweepJob, LpSweepJob};
use pats::scheduler::PatsScheduler;
use pats::shard::{ControlPlane, LpJob};
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;

const DEVICES: usize = 1024;

fn plane_and_jobs(shards: usize) -> (ControlPlane<PatsScheduler>, Vec<Vec<LpJob>>) {
    plane_and_jobs_with_broker(shards, false)
}

fn plane_and_jobs_with_broker(
    shards: usize,
    broker: bool,
) -> (ControlPlane<PatsScheduler>, Vec<Vec<LpJob>>) {
    let mut cfg = SystemConfig::default();
    cfg.devices = DEVICES;
    cfg.sharding.shards = shards;
    cfg.sharding.broker.enabled = broker;
    cfg.sharding.rebalance.enabled = broker;
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let deadline = SimTime::ZERO + cfg.frame_deadline();
    let mut jobs = vec![Vec::new(); shards];
    for d in 0..DEVICES as u32 {
        jobs[plane.home_shard(DeviceId(d))].push(LpJob {
            frame: FrameId(d as u64),
            source: DeviceId(d),
            n: 2,
            deadline,
            now: SimTime::ZERO,
        });
    }
    (plane, jobs)
}

/// A plane whose calendars already hold one admitted request per device —
/// the occupancy a mid-experiment decision sees.
fn loaded_plane(shards: usize) -> (ControlPlane<PatsScheduler>, SimTime) {
    let (mut plane, jobs) = plane_and_jobs(shards);
    plane.lp_sweep(&jobs, false);
    let cfg = SystemConfig::default();
    (plane, SimTime::ZERO + cfg.frame_deadline())
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let shard_counts = [1usize, 2, 4, 8];

    section("end-to-end decision sweep at 1024 devices: serial vs scoped threads");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("sweep_serial/devices={DEVICES}/shards={k}"),
            1,
            8,
            || plane_and_jobs(k),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, false).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("sweep_parallel/devices={DEVICES}/shards={k}"),
            1,
            8,
            || plane_and_jobs(k),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, true).len(),
        );
        show(&mut results, r);
    }

    section("batched-engine sweep doors (ControlSurface entry points)");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("surface_hp_sweep/devices={DEVICES}/shards={k}"),
            1,
            8,
            || {
                let (plane, _) = plane_and_jobs(k);
                let jobs: Vec<HpSweepJob> = (0..DEVICES as u32)
                    .map(|d| HpSweepJob {
                        frame: FrameId(d as u64),
                        source: DeviceId(d),
                        now: SimTime::ZERO,
                    })
                    .collect();
                (plane, jobs)
            },
            |(mut plane, jobs)| ControlSurface::hp_sweep(&mut plane, &jobs).len(),
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("surface_lp_sweep/devices={DEVICES}/shards={k}"),
            1,
            8,
            || {
                let (plane, jobs) = plane_and_jobs(k);
                let flat: Vec<LpSweepJob> = jobs
                    .iter()
                    .flatten()
                    .map(|j| LpSweepJob {
                        frame: j.frame,
                        source: j.source,
                        n: j.n,
                        deadline: j.deadline,
                        now: j.now,
                    })
                    .collect();
                (plane, flat)
            },
            |(mut plane, jobs)| ControlSurface::lp_request_sweep(&mut plane, &jobs).len(),
        );
        show(&mut results, r);
    }

    section("per-decision cost on a loaded plane (one admission, shard-local calendar)");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("admit_after_sweep/devices={DEVICES}/shards={k}"),
            1,
            20,
            || loaded_plane(k),
            |(mut plane, deadline)| {
                // One more request on an already-occupied fleet: the
                // admission's link-message and completion-point searches
                // run against the shard-local partition only.
                let (_, _, out) = plane.handle_lp_request(
                    FrameId(9_999),
                    DeviceId(7),
                    2,
                    deadline,
                    SimTime::ZERO,
                );
                out.placements.len()
            },
        );
        show(&mut results, r);
    }

    section("bandwidth broker: epoch cost and lease-aware admission");
    for &k in &shard_counts {
        // One full broker epoch (demand census + re-lease + rebalance scan)
        // on a loaded plane — the cost added at each prune barrier.
        let r = bench_with_setup(
            &format!("broker_epoch/devices={DEVICES}/shards={k}"),
            1,
            20,
            || {
                let (mut plane, jobs) = plane_and_jobs_with_broker(k, true);
                plane.lp_sweep(&jobs, false);
                let cfg = SystemConfig::default();
                (plane, SimTime::ZERO + cfg.frame_deadline())
            },
            |(mut plane, now)| {
                ControlSurface::epoch(&mut plane, now);
                plane.broker().epochs
            },
        );
        show(&mut results, r);

        // One admission after the broker has already re-leased: the spill
        // ring is re-ranked by current lease instead of walked statically.
        let r = bench_with_setup(
            &format!("admit_after_epoch/devices={DEVICES}/shards={k}"),
            1,
            20,
            || {
                let (mut plane, jobs) = plane_and_jobs_with_broker(k, true);
                plane.lp_sweep(&jobs, false);
                let cfg = SystemConfig::default();
                let deadline = SimTime::ZERO + cfg.frame_deadline();
                ControlSurface::epoch(&mut plane, deadline);
                (plane, deadline)
            },
            |(mut plane, deadline)| {
                let (_, _, out) = plane.handle_lp_request(
                    FrameId(9_999),
                    DeviceId(7),
                    2,
                    deadline,
                    SimTime::ZERO,
                );
                out.placements.len()
            },
        );
        show(&mut results, r);
    }

    match write_json("shards", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
