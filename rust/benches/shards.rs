//! Sharded-control-plane benchmarks: the same 1024-device decision load
//! at growing shard counts.
//!
//! Two claims are tracked across commits in `BENCH_shards.json`:
//!
//! * **Per-decision scheduling cost drops with shards.** Each shard owns
//!   its own link-calendar partition and only its own devices' occupancy,
//!   so one low-priority admission on a loaded plane touches a K-times
//!   smaller calendar (`admit_after_sweep/*`).
//! * **The end-to-end decision sweep parallelises.** Shards share no
//!   mutable state, so a batch decision phase runs one shard per OS
//!   thread (`std::thread::scope`); the 8-shard parallel sweep must beat
//!   the single-shard serial sweep (`sweep_parallel/*` vs
//!   `sweep_serial/shards=1`) — the first real wall-clock parallelism in
//!   the codebase.
//! * **The batched engine's sweep door scales too.** The parallel event
//!   loop (`sharding.engine = parallel`) reaches shards through
//!   `ControlSurface::{hp_sweep, lp_request_sweep}`; the
//!   `surface_hp_sweep/*` and `surface_lp_sweep/*` rows time those exact
//!   entry points on the 1024-device fixture so the engine's batch cost
//!   is tracked at every shard count.

use pats::bench::{bench_with_setup, section, smoke, write_json, BenchResult};
use pats::config::SystemConfig;
use pats::coordinator::{ControlSurface, HpSweepJob, LpSweepJob};
use pats::scheduler::PatsScheduler;
use pats::shard::{ControlPlane, LpJob};
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;

/// Default fleet size; `PATS_BENCH_SMOKE` shrinks it (see `main`).
const DEVICES: usize = 1024;

fn plane_and_jobs(
    devices: usize,
    shards: usize,
) -> (ControlPlane<PatsScheduler>, Vec<Vec<LpJob>>) {
    plane_and_jobs_with_broker(devices, shards, false)
}

fn plane_and_jobs_with_broker(
    devices: usize,
    shards: usize,
    broker: bool,
) -> (ControlPlane<PatsScheduler>, Vec<Vec<LpJob>>) {
    let mut cfg = SystemConfig::default();
    cfg.devices = devices;
    cfg.sharding.shards = shards;
    cfg.sharding.broker.enabled = broker;
    cfg.sharding.rebalance.enabled = broker;
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let deadline = SimTime::ZERO + cfg.frame_deadline();
    let mut jobs = vec![Vec::new(); shards];
    for d in 0..devices as u32 {
        jobs[plane.home_shard(DeviceId(d))].push(LpJob {
            frame: FrameId(d as u64),
            source: DeviceId(d),
            n: 2,
            deadline,
            now: SimTime::ZERO,
        });
    }
    (plane, jobs)
}

/// A plane whose calendars already hold one admitted request per device —
/// the occupancy a mid-experiment decision sees.
fn loaded_plane(devices: usize, shards: usize) -> (ControlPlane<PatsScheduler>, SimTime) {
    let (mut plane, jobs) = plane_and_jobs(devices, shards);
    plane.lp_sweep(&jobs, false);
    let cfg = SystemConfig::default();
    (plane, SimTime::ZERO + cfg.frame_deadline())
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let shard_counts = [1usize, 2, 4, 8];
    let devices = if smoke() { 256 } else { DEVICES };
    let iters = if smoke() { 3 } else { 8 };
    let loaded_iters = if smoke() { 5 } else { 20 };

    section("end-to-end decision sweep: serial vs scoped threads");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("sweep_serial/devices={devices}/shards={k}"),
            1,
            iters,
            || plane_and_jobs(devices, k),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, false).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("sweep_parallel/devices={devices}/shards={k}"),
            1,
            iters,
            || plane_and_jobs(devices, k),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, true).len(),
        );
        show(&mut results, r);
    }

    section("batched-engine sweep doors (ControlSurface entry points)");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("surface_hp_sweep/devices={devices}/shards={k}"),
            1,
            iters,
            || {
                let (plane, _) = plane_and_jobs(devices, k);
                let jobs: Vec<HpSweepJob> = (0..devices as u32)
                    .map(|d| HpSweepJob {
                        frame: FrameId(d as u64),
                        source: DeviceId(d),
                        now: SimTime::ZERO,
                    })
                    .collect();
                (plane, jobs)
            },
            |(mut plane, jobs)| ControlSurface::hp_sweep(&mut plane, &jobs).len(),
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("surface_lp_sweep/devices={devices}/shards={k}"),
            1,
            iters,
            || {
                let (plane, jobs) = plane_and_jobs(devices, k);
                let flat: Vec<LpSweepJob> = jobs
                    .iter()
                    .flatten()
                    .map(|j| LpSweepJob {
                        frame: j.frame,
                        source: j.source,
                        n: j.n,
                        deadline: j.deadline,
                        now: j.now,
                    })
                    .collect();
                (plane, flat)
            },
            |(mut plane, jobs)| ControlSurface::lp_request_sweep(&mut plane, &jobs).len(),
        );
        show(&mut results, r);
    }

    section("per-decision cost on a loaded plane (one admission, shard-local calendar)");
    for &k in &shard_counts {
        let r = bench_with_setup(
            &format!("admit_after_sweep/devices={devices}/shards={k}"),
            1,
            loaded_iters,
            || loaded_plane(devices, k),
            |(mut plane, deadline)| {
                // One more request on an already-occupied fleet: the
                // admission's link-message and completion-point searches
                // run against the shard-local partition only.
                let (_, _, out) = plane.handle_lp_request(
                    FrameId(9_999),
                    DeviceId(7),
                    2,
                    deadline,
                    SimTime::ZERO,
                );
                out.placements.len()
            },
        );
        show(&mut results, r);
    }

    section("bandwidth broker: epoch cost and lease-aware admission");
    for &k in &shard_counts {
        // One full broker epoch (demand census + re-lease + rebalance scan)
        // on a loaded plane — the cost added at each prune barrier.
        let r = bench_with_setup(
            &format!("broker_epoch/devices={devices}/shards={k}"),
            1,
            loaded_iters,
            || {
                let (mut plane, jobs) = plane_and_jobs_with_broker(devices, k, true);
                plane.lp_sweep(&jobs, false);
                let cfg = SystemConfig::default();
                (plane, SimTime::ZERO + cfg.frame_deadline())
            },
            |(mut plane, now)| {
                ControlSurface::epoch(&mut plane, now);
                plane.broker().epochs
            },
        );
        show(&mut results, r);

        // One admission after the broker has already re-leased: the spill
        // ring is re-ranked by current lease instead of walked statically.
        let r = bench_with_setup(
            &format!("admit_after_epoch/devices={devices}/shards={k}"),
            1,
            loaded_iters,
            || {
                let (mut plane, jobs) = plane_and_jobs_with_broker(devices, k, true);
                plane.lp_sweep(&jobs, false);
                let cfg = SystemConfig::default();
                let deadline = SimTime::ZERO + cfg.frame_deadline();
                ControlSurface::epoch(&mut plane, deadline);
                (plane, deadline)
            },
            |(mut plane, deadline)| {
                let (_, _, out) = plane.handle_lp_request(
                    FrameId(9_999),
                    DeviceId(7),
                    2,
                    deadline,
                    SimTime::ZERO,
                );
                out.placements.len()
            },
        );
        show(&mut results, r);
    }

    section("fleet scale: the 10k-device row");
    // The availability index is what makes these complete in bench time:
    // each shard's NetworkState is fleet-sized, so every admission's
    // candidate pre-filter used to walk all 10k calendars.
    let big = if smoke() { 1_024 } else { 10_240 };
    for &k in &[8usize] {
        let r = bench_with_setup(
            &format!("sweep_parallel/devices={big}/shards={k}"),
            0,
            if smoke() { 2 } else { 4 },
            || plane_and_jobs(big, k),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, true).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("admit_after_sweep/devices={big}/shards={k}"),
            0,
            if smoke() { 2 } else { 4 },
            || loaded_plane(big, k),
            |(mut plane, deadline)| {
                let (_, _, out) = plane.handle_lp_request(
                    FrameId(99_999),
                    DeviceId(7),
                    2,
                    deadline,
                    SimTime::ZERO,
                );
                out.placements.len()
            },
        );
        show(&mut results, r);
    }

    match write_json("shards", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
