//! Multi-fidelity benchmark: the four-policy degradation sweep across the
//! PR 1 fleet sizes (`fleet.sweep_sizes`, default 4/64/256/1024 devices),
//! timed, with the full degradation census recorded to
//! `BENCH_fidelity.json`. `cargo bench --bench fidelity` is the
//! release-mode run behind the acceptance claim that enabling degradation
//! never completes fewer frames than the paper's reject-or-fail behaviour.

use pats::config::SystemConfig;
use pats::experiments::{fidelity, fidelity_json, fidelity_table};
use pats::util::json::Json;

fn main() {
    let cfg = SystemConfig::default();
    let sizes = cfg.fleet.sweep_sizes.clone();
    println!(
        "running the fidelity sweep at {sizes:?} devices × {} cycles, {}% crash \
         (seed {:#x}) ...",
        cfg.fidelity.cycles, cfg.fidelity.crash_pct, cfg.seed
    );
    let t0 = std::time::Instant::now();
    let rows = fidelity(&cfg, &sizes);
    let wall = t0.elapsed();
    println!("sweep complete in {wall:.2?}\n");
    println!("{}", fidelity_table(&rows));

    for &devices in &sizes {
        let frames = |tag: &str| {
            rows.iter()
                .find(|r| r.label == format!("{tag}_{devices}"))
                .map(|r| r.metrics.frames_completed)
                .unwrap_or(0)
        };
        println!(
            "{devices} devices: frames completed off {} vs full degradation {}",
            frames("FID_OFF"),
            frames("FID_FULL")
        );
    }

    let doc = Json::obj()
        .with("bench", "fidelity")
        .with("sweep_wall_ms", wall.as_secs_f64() * 1_000.0)
        .with("sweep", fidelity_json(&rows));
    match std::fs::write("BENCH_fidelity.json", doc.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_fidelity.json"),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
