//! Reservation-calendar micro-benchmarks: the data structures under every
//! scheduling decision (earliest-fit search, reservation insert, preemption
//! candidate selection, completion-point enumeration) at increasing
//! occupancy.

use pats::bench::{bench_with_setup, section};
use pats::resources::{CoreTimeline, SlotKind, Timeline};
use pats::task::{TaskId, Window};
use pats::time::{SimDuration, SimTime};

fn filled_timeline(n: usize) -> Timeline {
    let mut tl = Timeline::new();
    for i in 0..n {
        // 1 ms slots with 1 ms gaps.
        let start = SimTime::from_micros(2_000 * i as u64);
        tl.reserve(start, SimDuration::from_millis(1), SlotKind::StateUpdate, TaskId(i as u64))
            .unwrap();
    }
    tl
}

fn filled_cores(n: usize) -> CoreTimeline {
    let mut ct = CoreTimeline::new(4);
    for i in 0..n {
        let start = SimTime::from_secs_f64(18.0 * (i / 2) as f64);
        ct.reserve(
            Window::from_duration(start, SimDuration::from_secs_f64(17.0)),
            2,
            TaskId(i as u64),
            start + SimDuration::from_secs_f64(60.0),
            true,
        )
        .unwrap();
    }
    ct
}

fn main() {
    section("link timeline: earliest_fit");
    for n in [10usize, 100, 1_000, 10_000] {
        let tl = filled_timeline(n);
        let mut r = bench_with_setup(
            &format!("earliest_fit/slots={n}"),
            50,
            2_000,
            || (),
            |_| tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(1_500)),
        );
        println!("{}", r.render());
    }

    section("link timeline: reserve + remove");
    for n in [100usize, 1_000, 10_000] {
        let mut r = bench_with_setup(
            &format!("reserve_remove/slots={n}"),
            10,
            400,
            || filled_timeline(n),
            |mut tl| {
                let start = tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(500));
                tl.reserve(start, SimDuration::from_micros(500), SlotKind::PollMsg, TaskId(u64::MAX))
                    .unwrap();
                tl.remove_owner(TaskId(u64::MAX))
            },
        );
        println!("{}", r.render());
    }

    section("core timeline: fits / preemption candidates / completion points");
    for n in [8usize, 64, 512] {
        let ct = filled_cores(n);
        let probe = Window::new(SimTime::from_secs_f64(1.0), SimTime::from_secs_f64(18.0));
        let mut r = bench_with_setup(
            &format!("fits/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.fits(&probe, 1),
        );
        println!("{}", r.render());
        let mut r = bench_with_setup(
            &format!("preemption_candidates/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.preemption_candidates(&probe).len(),
        );
        println!("{}", r.render());
        let mut r = bench_with_setup(
            &format!("completion_points/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.completion_points(SimTime::ZERO, SimTime::from_secs_f64(1e6)).len(),
        );
        println!("{}", r.render());
    }
}
