//! Reservation-calendar micro-benchmarks: the data structures under every
//! scheduling decision (earliest-fit search, reservation insert, preemption
//! candidate selection, completion-point enumeration) at increasing
//! occupancy, plus a fleet-size sweep (4 → 1024 devices) over the
//! gap-indexed link calendar.
//!
//! Results are printed and recorded to `BENCH_timeline.json`, so the
//! sublinear growth of `earliest_fit` + `reserve` in reserved-slot count is
//! measurable across commits.

use pats::bench::{bench_with_setup, section, write_json, BenchResult};
use pats::resources::{CoreTimeline, SlotKind, Timeline};
use pats::task::{TaskId, Window};
use pats::time::{SimDuration, SimTime};

fn filled_timeline(n: usize) -> Timeline {
    let mut tl = Timeline::new();
    for i in 0..n {
        // 1 ms slots with 1 ms gaps.
        let start = SimTime::from_micros(2_000 * i as u64);
        tl.reserve(start, SimDuration::from_millis(1), SlotKind::StateUpdate, TaskId(i as u64))
            .unwrap();
    }
    tl
}

fn filled_cores(n: usize) -> CoreTimeline {
    let mut ct = CoreTimeline::new(4);
    for i in 0..n {
        let start = SimTime::from_secs_f64(18.0 * (i / 2) as f64);
        ct.reserve(
            Window::from_duration(start, SimDuration::from_secs_f64(17.0)),
            2,
            TaskId(i as u64),
            start + SimDuration::from_secs_f64(60.0),
            true,
        )
        .unwrap();
    }
    ct
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();

    section("link timeline: earliest_fit");
    for n in [10usize, 100, 1_000, 10_000] {
        let tl = filled_timeline(n);
        let r = bench_with_setup(
            &format!("earliest_fit/slots={n}"),
            50,
            2_000,
            || (),
            |_| tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(1_500)),
        );
        show(&mut results, r);
    }

    section("link timeline: reserve + remove");
    for n in [100usize, 1_000, 10_000] {
        let r = bench_with_setup(
            &format!("reserve_remove/slots={n}"),
            10,
            400,
            || filled_timeline(n),
            |mut tl| {
                let start = tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(500));
                tl.reserve(start, SimDuration::from_micros(500), SlotKind::PollMsg, TaskId(u64::MAX))
                    .unwrap();
                tl.remove_owner(TaskId(u64::MAX))
            },
        );
        show(&mut results, r);
    }

    // The fleet sweep models the shared link of an n-device fleet: ~16 live
    // reservations per device, and one scheduling decision = one
    // earliest-fit probe + one reserve + one owner removal. The acceptance
    // criterion for the gap index is that this cost grows sublinearly in
    // the reserved-slot count.
    section("fleet sweep: earliest_fit + reserve + remove at 4/64/256/1024 devices");
    for devices in [4usize, 64, 256, 1_024] {
        let slots = devices * 16;
        let r = bench_with_setup(
            &format!("fleet_fit_reserve/devices={devices}/slots={slots}"),
            5,
            200,
            || filled_timeline(slots),
            |mut tl| {
                // A mid-horizon probe, like a controller planning from "now".
                let now = SimTime::from_micros(1_000 * slots as u64);
                let dur = SimDuration::from_micros(1_500);
                let start = tl.earliest_fit(now, dur);
                tl.reserve(start, dur, SlotKind::LpAllocMsg, TaskId(u64::MAX)).unwrap();
                tl.remove_owner(TaskId(u64::MAX))
            },
        );
        show(&mut results, r);
    }

    // The gap index's documented worst case (KNOWN_ISSUES §gap index):
    // gaps whose length shares the request's ⌊log₂⌋ bucket but still does
    // not fit must be length-checked one by one, degrading toward a scan
    // of that bucket. Here every interior gap is 1.2 ms against a 1.5 ms
    // request (same class-10 bucket, 1024..2047 µs), so `earliest_fit`
    // walks all of them before settling on the trailing gap — this case
    // tracks the degradation across commits instead of leaving it
    // anecdotal.
    section("gap index: ambiguous length bucket (documented worst case)");
    for n in [100usize, 1_000, 10_000] {
        let mut tl = Timeline::new();
        for i in 0..n {
            // 800 µs slots at a 2 ms stride: every interior gap is 1.2 ms.
            tl.reserve(
                SimTime::from_micros(2_000 * i as u64),
                SimDuration::from_micros(800),
                SlotKind::StateUpdate,
                TaskId(i as u64),
            )
            .unwrap();
        }
        let r = bench_with_setup(
            &format!("ambiguous_bucket/gaps={n}"),
            20,
            1_000,
            || (),
            |_| tl.earliest_fit(SimTime::ZERO, SimDuration::from_micros(1_500)),
        );
        show(&mut results, r);
    }

    section("core timeline: fits / preemption candidates / completion points");
    for n in [8usize, 64, 512] {
        let ct = filled_cores(n);
        let probe = Window::new(SimTime::from_secs_f64(1.0), SimTime::from_secs_f64(18.0));
        let r = bench_with_setup(
            &format!("fits/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.fits(&probe, 1),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("preemption_candidates/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.preemption_candidates(&probe).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("completion_points/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.completion_points(SimTime::ZERO, SimTime::from_secs_f64(1e6)).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("earliest_availability/slots={n}"),
            50,
            2_000,
            || (),
            |_| ct.earliest_availability(SimTime::from_secs_f64(1.0), 4),
        );
        show(&mut results, r);
    }

    match write_json("timeline", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
