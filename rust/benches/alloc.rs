//! Controller hot-path micro-benchmarks (Fig 9 / Fig 10 at the
//! algorithm level): high-priority allocation, the preemption path, and
//! low-priority request allocation, each at increasing network load.

use pats::bench::{bench_with_setup, section};
use pats::config::SystemConfig;
use pats::scheduler::plan::PlacementPlan;
use pats::scheduler::{PatsScheduler, Policy};
use pats::state::NetworkState;
use pats::task::{Allocation, DeviceId, FrameId, LpRequest, Priority, TaskSpec, Window};
use pats::time::SimTime;

/// Commit one placement through the transactional planning layer.
fn place(st: &mut NetworkState, alloc: Allocation) {
    let mut plan = PlacementPlan::new(st);
    plan.stage_placement(st, alloc).unwrap();
    st.apply(plan).unwrap();
}

/// Build a network state pre-loaded with `load` low-priority allocations
/// spread across devices (the paper's search-time driver, §6.3).
fn loaded_state(cfg: &SystemConfig, load: usize) -> NetworkState {
    let mut st = NetworkState::new(cfg);
    for i in 0..load {
        let id = st.fresh_task_id();
        let dev = DeviceId((i % cfg.devices) as u32);
        let start = SimTime::from_secs_f64(20.0 + (i / cfg.devices) as f64 * 18.0);
        st.register_task(TaskSpec {
            id,
            frame: FrameId(i as u64),
            source: dev,
            priority: Priority::Low,
            deadline: start + pats::time::SimDuration::from_secs_f64(60.0),
            spawn: SimTime::ZERO,
            request: None,
        });
        place(&mut st, Allocation {
            task: id,
            device: dev,
            window: Window::from_duration(start, cfg.lp_slot(2)),
            cores: 2,
            offloaded: false,
        });
    }
    st
}

fn hp_spec(st: &mut NetworkState, cfg: &SystemConfig) -> pats::task::TaskId {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(999),
        source: DeviceId(0),
        priority: Priority::High,
        deadline: SimTime::from_secs_f64(cfg.hp_deadline_s),
        spawn: SimTime::ZERO,
        request: None,
    });
    id
}

fn lp_request(st: &mut NetworkState, n: usize) -> pats::task::RequestId {
    let rid = st.fresh_request_id();
    let deadline = SimTime::from_secs_f64(18.86);
    let mut tasks = Vec::new();
    for _ in 0..n {
        let id = st.fresh_task_id();
        st.register_task(TaskSpec {
            id,
            frame: FrameId(998),
            source: DeviceId(0),
            priority: Priority::Low,
            deadline,
            spawn: SimTime::ZERO,
            request: Some(rid),
        });
        tasks.push(id);
    }
    st.register_request(LpRequest {
        id: rid,
        frame: FrameId(998),
        source: DeviceId(0),
        deadline,
        spawn: SimTime::ZERO,
        tasks,
    });
    rid
}

fn main() {
    let cfg = SystemConfig::default();

    section("high-priority allocation (Fig 9a)");
    for load in [0usize, 8, 32, 128] {
        let r = bench_with_setup(
            &format!("hp_alloc/load={load}"),
            20,
            300,
            || {
                let mut st = loaded_state(&cfg, load);
                let task = hp_spec(&mut st, &cfg);
                (st, task, PatsScheduler { preemption: true, reallocate: true, set_aware_victims: false })
            },
            |(mut st, task, mut sched)| sched.allocate_hp(&mut st, &cfg, task, SimTime::ZERO),
        );
        println!("{}", r.render());
    }

    section("high-priority allocation with preemption firing (Fig 9b)");
    for load in [8usize, 32, 128] {
        let r = bench_with_setup(
            &format!("hp_alloc_preempt/load={load}"),
            20,
            300,
            || {
                let mut st = loaded_state(&cfg, load);
                // Saturate the source device so the HP attempt must preempt.
                let blocker = st.fresh_task_id();
                st.register_task(TaskSpec {
                    id: blocker,
                    frame: FrameId(997),
                    source: DeviceId(0),
                    priority: Priority::Low,
                    deadline: SimTime::from_secs_f64(90.0),
                    spawn: SimTime::ZERO,
                    request: None,
                });
                place(&mut st, Allocation {
                    task: blocker,
                    device: DeviceId(0),
                    window: Window::new(SimTime::ZERO, SimTime::from_secs_f64(17.0)),
                    cores: 4,
                    offloaded: false,
                });
                let task = hp_spec(&mut st, &cfg);
                (st, task, PatsScheduler { preemption: true, reallocate: true, set_aware_victims: false })
            },
            |(mut st, task, mut sched)| {
                let out = sched.allocate_hp(&mut st, &cfg, task, SimTime::ZERO);
                assert!(out.preemption.is_some());
                out
            },
        );
        println!("{}", r.render());
    }

    section("low-priority request allocation (Fig 10)");
    for (n, load) in [(1usize, 0usize), (4, 0), (1, 64), (4, 64), (4, 256)] {
        let r = bench_with_setup(
            &format!("lp_alloc/tasks={n}/load={load}"),
            10,
            200,
            || {
                let mut st = loaded_state(&cfg, load);
                let rid = lp_request(&mut st, n);
                (st, rid, PatsScheduler { preemption: true, reallocate: true, set_aware_victims: false })
            },
            |(mut st, rid, mut sched)| sched.allocate_lp(&mut st, &cfg, rid, SimTime::ZERO),
        );
        println!("{}", r.render());
    }
}
