//! Fleet-scale benchmarks: the availability index against the direct
//! O(N) scan, and the end-to-end 10k-device sweep.
//!
//! Two claims are tracked across commits:
//!
//! * **The availability index beats the direct scan at 1k+ devices**
//!   (`BENCH_fleet.json`). The low-priority offload pre-filter and the
//!   rescue candidate scan both ranked every up device per time-point;
//!   the index answers the settled majority of the fleet in O(1) per
//!   device, so candidate selection scales with the *busy* devices. Each
//!   case runs twice — `index=off` is the legacy scan, `index=on` the
//!   indexed door — on bit-identical fixtures.
//! * **A 10k-device fleet sweep completes end-to-end**
//!   (`BENCH_fleet10k.json`), with the profiler's per-phase breakdown
//!   (event loop, planning layer, placement paths) attached so regressions
//!   are attributable to a phase, not just a total.
//!
//! `PATS_BENCH_SMOKE=1` (`make bench-smoke`) shrinks the fleet sizes and
//! iteration counts to a CI-friendly profile with the same row shapes.

use pats::bench::{bench, bench_with_setup, section, smoke, write_json, BenchResult};
use pats::config::SystemConfig;
use pats::resources::avail;
use pats::scheduler::plan::PlacementPlan;
use pats::scheduler::{PatsScheduler, Policy};
use pats::state::NetworkState;
use pats::task::{Allocation, DeviceId, FrameId, LpRequest, Priority, TaskSpec, Window};
use pats::time::{SimDuration, SimTime};
use pats::util::profiler;

/// Commit one placement through the transactional planning layer.
fn place(st: &mut NetworkState, alloc: Allocation) {
    let mut plan = PlacementPlan::new(st);
    plan.stage_placement(st, alloc).unwrap();
    st.apply(plan).unwrap();
}

/// A fleet-sized state with `load` low-priority allocations spread across
/// the first `load` devices — the rest of the fleet is idle (settled), the
/// occupancy profile the index exploits.
fn loaded_fleet(devices: usize, load: usize) -> (SystemConfig, NetworkState) {
    let mut cfg = SystemConfig::default();
    cfg.devices = devices;
    let mut st = NetworkState::new(&cfg);
    for i in 0..load {
        let id = st.fresh_task_id();
        let dev = DeviceId((i % devices) as u32);
        let start = SimTime::from_secs_f64(20.0 + (i / devices) as f64 * 18.0);
        st.register_task(TaskSpec {
            id,
            frame: FrameId(i as u64),
            source: dev,
            priority: Priority::Low,
            deadline: start + SimDuration::from_secs_f64(60.0),
            spawn: SimTime::ZERO,
            request: None,
        });
        place(&mut st, Allocation {
            task: id,
            device: dev,
            window: Window::from_duration(start, cfg.lp_slot(2)),
            cores: 2,
            offloaded: false,
        });
    }
    (cfg, st)
}

fn lp_request(st: &mut NetworkState, n: usize) -> pats::task::RequestId {
    let rid = st.fresh_request_id();
    let deadline = SimTime::from_secs_f64(18.86);
    let mut tasks = Vec::new();
    for _ in 0..n {
        let id = st.fresh_task_id();
        st.register_task(TaskSpec {
            id,
            frame: FrameId(998),
            source: DeviceId(0),
            priority: Priority::Low,
            deadline,
            spawn: SimTime::ZERO,
            request: Some(rid),
        });
        tasks.push(id);
    }
    st.register_request(LpRequest {
        id: rid,
        frame: FrameId(998),
        source: DeviceId(0),
        deadline,
        spawn: SimTime::ZERO,
        tasks,
    });
    rid
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let sizes: &[usize] = if smoke() { &[256] } else { &[1_024, 10_240] };
    let iters = if smoke() { 3 } else { 6 };

    section("LP offload pre-filter: direct O(N) scan vs availability index");
    for &devices in sizes {
        // An eighth of the fleet is busy; the rest is settled — the index
        // answers those without touching their calendars.
        let load = devices / 8;
        for index_on in [false, true] {
            let tag = if index_on { "on" } else { "off" };
            let r = bench_with_setup(
                &format!("lp_admit/devices={devices}/index={tag}"),
                1,
                iters,
                || {
                    let (cfg, mut st) = loaded_fleet(devices, load);
                    let rid = lp_request(&mut st, 4);
                    let sched = PatsScheduler {
                        preemption: true,
                        reallocate: true,
                        set_aware_victims: false,
                    };
                    (cfg, st, rid, sched)
                },
                |(cfg, mut st, rid, mut sched)| {
                    avail::set_enabled(index_on);
                    let out = sched.allocate_lp(&mut st, &cfg, rid, SimTime::ZERO);
                    avail::set_enabled(true);
                    assert!(!out.placements.is_empty(), "fleet has room for the set");
                    out.placements.len()
                },
            );
            show(&mut results, r);
        }
    }

    section("rescue candidate scan: direct O(N) scan vs availability index");
    for &devices in sizes {
        let load = devices / 8;
        for index_on in [false, true] {
            let tag = if index_on { "on" } else { "off" };
            let r = bench_with_setup(
                &format!("rescue_scan/devices={devices}/index={tag}"),
                1,
                iters,
                || loaded_fleet(devices, load).1,
                |st| {
                    avail::set_enabled(index_on);
                    // Several windows per round, as rescue_all scans one
                    // window per orphaned task.
                    let mut total = 0usize;
                    for w in 0..4u64 {
                        let window = Window::new(
                            SimTime::from_secs_f64(w as f64),
                            SimTime::from_secs_f64(w as f64 + 5.0),
                        );
                        total += avail::rescue_candidates(&st, DeviceId(0), &window).len();
                    }
                    avail::set_enabled(true);
                    total
                },
            );
            show(&mut results, r);
        }
    }

    avail::set_enabled(true);
    match write_json("fleet", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }

    // ---- the 10k-device sweep, profiled -------------------------------
    // One full fleet_scale run through the real simulation engine; the
    // profiler's per-phase breakdown lands in BENCH_fleet10k.json.
    section("end-to-end fleet sweep with per-phase profile");
    let mut sweep_results: Vec<BenchResult> = Vec::new();
    let devices = if smoke() { 512 } else { 10_000 };
    let mut cfg = SystemConfig::default();
    cfg.fleet.cycles = 2;
    profiler::enable(true);
    profiler::reset();
    let r = bench(
        &format!("fleet_sweep/devices={devices}/cycles={}", cfg.fleet.cycles),
        0,
        1,
        || {
            let rows = pats::experiments::fleet_scale(&cfg, &[devices]);
            let row = &rows[0];
            assert_eq!(row.devices, devices);
            assert!(row.metrics.frames_total > 0, "the sweep must complete end-to-end");
            println!(
                "  {} devices: {} frames, {} completed, wall {:.2?}, virtual end {}",
                row.devices,
                row.metrics.frames_total,
                row.metrics.frames_completed,
                row.wall,
                row.virtual_end
            );
            row.metrics.frames_completed
        },
    );
    show(&mut sweep_results, r);
    match write_json("fleet10k", &sweep_results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
    profiler::enable(false);
}
