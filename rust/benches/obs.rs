//! Flight-recorder benchmarks: what tracing costs when it is off, when it
//! is on, and what the JSONL serializer sustains.
//!
//! Three claims are tracked across commits in `BENCH_obs.json`:
//!
//! * **Off is free.** The recorder is checked once per run at `Sim`
//!   construction, not per event, so `trace_off_floor/*` is the plain
//!   simulation wall clock — any regression here is recorder cost leaking
//!   into untraced runs.
//! * **On is bounded.** `trace_on_overhead/*` runs the identical scenario
//!   with the recorder armed (emit into the thread-local ring, barrier
//!   flushes, canonical sort, decomposition and histogram fold) — the gap
//!   to the floor row is the full price of `--trace`.
//! * **Export scales with the journal.** `export_jsonl/*` serializes a
//!   retained run to its line-per-event form.

use pats::bench::{bench, bench_with_setup, section, smoke, write_json, BenchResult};
use pats::config::SystemConfig;
use pats::obs;
use pats::sim::run_scenario;
use pats::trace::{Distribution, Trace};

fn fixture(frames: u64) -> (SystemConfig, Trace) {
    let mut cfg = SystemConfig::default();
    cfg.frames = frames;
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    (cfg, trace)
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let frames = if smoke() { 40 } else { 400 };
    let iters = if smoke() { 2 } else { 6 };
    let (cfg, trace) = fixture(frames);

    section("recorder cost on the seed scenario");
    obs::enable(false);
    let r = bench(&format!("trace_off_floor/frames={frames}"), 1, iters, || {
        run_scenario(&cfg, &trace, "off").metrics.frames_completed
    });
    show(&mut results, r);
    let r = bench(&format!("trace_on_overhead/frames={frames}"), 1, iters, || {
        obs::enable(true);
        let out = run_scenario(&cfg, &trace, "on");
        obs::enable(false);
        // Drop the retained run so repeated iterations do not accumulate
        // journals in the recorder's process-wide store.
        let _ = obs::take_recorded();
        out.metrics.frames_completed
    });
    show(&mut results, r);

    section("JSONL export throughput");
    let r = bench_with_setup(
        &format!("export_jsonl/frames={frames}"),
        0,
        iters,
        || {
            obs::enable(true);
            let _ = run_scenario(&cfg, &trace, "export");
            obs::enable(false);
            obs::take_recorded()
        },
        |runs| obs::export::jsonl(&runs).len(),
    );
    show(&mut results, r);

    match write_json("obs", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
