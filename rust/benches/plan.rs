//! Transactional-plan admission throughput (writes `BENCH_plan.json`).
//!
//! The planning layer trades direct mutate-and-rollback for staged
//! scratch-copy transactions; this bench tracks what that costs on the
//! admission hot paths across the fleet sizes of the PR 1 sweep
//! (`fleet.sweep_sizes`, default 4/64/256/1024 devices):
//!
//! * `lp_admit_batched` — one 4-task request admitted as ONE plan
//!   (`allocate_request`), the production path.
//! * `lp_admit_per_task` — the same four tasks admitted as four separate
//!   single-task transactions (`allocate_single`), the shape of the
//!   pre-plan path that re-read completion points between siblings. The
//!   batched path should stay at or below this line.
//! * `hp_admit` — the three-slot high-priority plan.
//! * `plan_open_drop` — open a plan against a loaded state, fork the link
//!   scratch, and drop it (the floor a *rejected* candidate plan pays when
//!   the reuse pool is cold: a full link-calendar clone).
//! * `plan_open_drop_pooled` — the same open-and-drop with the pool warmed
//!   by an untimed fork in setup, so every timed fork is a pool hit and
//!   rollback replaces the clone. Should sit measurably below
//!   `plan_open_drop` at the big end of the sweep.
//! * `link_clone_floor` — a bare `link().clone()`, the cost the pool
//!   amortises away.

use pats::bench::{bench_with_setup, section, write_json, BenchResult};
use pats::config::SystemConfig;
use pats::scheduler::plan::PlacementPlan;
use pats::scheduler::{PatsScheduler, Policy};
use pats::state::NetworkState;
use pats::task::{Allocation, DeviceId, FrameId, LpRequest, Priority, RequestId, TaskId, TaskSpec, Window};
use pats::time::{SimDuration, SimTime};

/// A state with `devices` devices, pre-loaded with ~2 LP allocations per
/// device plus their state-update link slots — the paper's search-time
/// driver scaled to fleet size.
fn loaded_state(devices: usize) -> (SystemConfig, NetworkState) {
    let mut cfg = SystemConfig::default();
    cfg.devices = devices;
    let mut st = NetworkState::new(&cfg);
    // Register everything first, then stage the whole pre-load as ONE plan:
    // the link scratch is forked once instead of once per placement.
    let mut specs = Vec::new();
    for i in 0..devices * 2 {
        let id = st.fresh_task_id();
        let dev = DeviceId((i % devices) as u32);
        let start = SimTime::from_secs_f64(25.0 + (i / devices) as f64 * 19.0);
        let deadline = start + SimDuration::from_secs_f64(60.0);
        st.register_task(TaskSpec {
            id,
            frame: FrameId(i as u64),
            source: dev,
            priority: Priority::Low,
            deadline,
            spawn: SimTime::ZERO,
            request: None,
        });
        specs.push((id, dev, start));
    }
    let update_dur = st.link_model.slot_duration(&cfg, pats::resources::SlotKind::StateUpdate);
    let mut plan = PlacementPlan::new(&st);
    for (id, dev, start) in specs {
        plan.stage_placement(&st, Allocation {
            task: id,
            device: dev,
            window: Window::from_duration(start, cfg.lp_slot(2)),
            cores: 2,
            offloaded: false,
        })
        .unwrap();
        plan.stage_link_earliest(
            &st,
            start + cfg.lp_slot(2),
            update_dur,
            pats::resources::SlotKind::StateUpdate,
            id,
        );
    }
    st.apply(plan).unwrap();
    (cfg, st)
}

fn lp_request(st: &mut NetworkState, n: usize) -> (RequestId, Vec<TaskId>) {
    let rid = st.fresh_request_id();
    let deadline = SimTime::from_secs_f64(18.86);
    let mut tasks = Vec::new();
    for _ in 0..n {
        let id = st.fresh_task_id();
        st.register_task(TaskSpec {
            id,
            frame: FrameId(u64::MAX),
            source: DeviceId(0),
            priority: Priority::Low,
            deadline,
            spawn: SimTime::ZERO,
            request: Some(rid),
        });
        tasks.push(id);
    }
    st.register_request(LpRequest {
        id: rid,
        frame: FrameId(u64::MAX),
        source: DeviceId(0),
        deadline,
        spawn: SimTime::ZERO,
        tasks: tasks.clone(),
    });
    (rid, tasks)
}

fn hp_spec(st: &mut NetworkState, cfg: &SystemConfig) -> TaskId {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(u64::MAX - 1),
        source: DeviceId(0),
        priority: Priority::High,
        deadline: SimTime::from_secs_f64(cfg.hp_deadline_s),
        spawn: SimTime::ZERO,
        request: None,
    });
    id
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let sizes = SystemConfig::default().fleet.sweep_sizes.clone();
    let mut results: Vec<BenchResult> = Vec::new();

    for &devices in &sizes {
        section(&format!("admission at {devices} devices"));
        // Per-iteration setup rebuilds the loaded fleet; keep wall time
        // bounded at the big end of the sweep.
        let (warmup, iters) = if devices >= 256 { (3u32, 40u32) } else { (10, 150) };

        let r = bench_with_setup(
            &format!("lp_admit_batched/devices={devices}"),
            warmup,
            iters,
            || {
                let (cfg, mut st) = loaded_state(devices);
                let (rid, _) = lp_request(&mut st, 4);
                (cfg, st, rid)
            },
            |(cfg, mut st, rid)| {
                let mut sched = PatsScheduler::from_config(&cfg);
                let out = sched.allocate_lp(&mut st, &cfg, rid, SimTime::ZERO);
                assert!(out.fully_allocated(), "idle fleet must admit the set");
                out
            },
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("lp_admit_per_task/devices={devices}"),
            warmup,
            iters,
            || {
                let (cfg, mut st) = loaded_state(devices);
                let (_, tasks) = lp_request(&mut st, 4);
                (cfg, st, tasks)
            },
            |(cfg, mut st, tasks)| {
                for &t in &tasks {
                    let p = pats::scheduler::low_priority::allocate_single(
                        &mut st,
                        &cfg,
                        t,
                        SimTime::ZERO,
                    );
                    assert!(p.is_some());
                }
            },
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("hp_admit/devices={devices}"),
            warmup,
            iters,
            || {
                let (cfg, mut st) = loaded_state(devices);
                let task = hp_spec(&mut st, &cfg);
                (cfg, st, task)
            },
            |(cfg, mut st, task)| {
                let mut sched = PatsScheduler::from_config(&cfg);
                let out = sched.allocate_hp(&mut st, &cfg, task, SimTime::ZERO);
                assert!(out.allocated());
                out
            },
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("plan_open_drop/devices={devices}"),
            warmup,
            iters * 2,
            || loaded_state(devices),
            |(cfg, st)| {
                // The floor a rejected candidate pays: fork the link
                // scratch with one staged slot, then drop everything.
                let mut plan = PlacementPlan::new(&st);
                let dur = st
                    .link_model
                    .slot_duration(&cfg, pats::resources::SlotKind::LpAllocMsg);
                plan.stage_link_earliest(
                    &st,
                    SimTime::ZERO,
                    dur,
                    pats::resources::SlotKind::LpAllocMsg,
                    TaskId(u64::MAX),
                );
                drop(plan);
            },
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("plan_open_drop_pooled/devices={devices}"),
            warmup,
            iters * 2,
            || {
                let (cfg, st) = loaded_state(devices);
                // Untimed warm-up fork: its rollback parks a scratch
                // timeline in the thread-local pool keyed to this state,
                // so the timed fork below is a pool hit.
                let dur = st
                    .link_model
                    .slot_duration(&cfg, pats::resources::SlotKind::LpAllocMsg);
                let mut plan = PlacementPlan::new(&st);
                plan.stage_link_earliest(
                    &st,
                    SimTime::ZERO,
                    dur,
                    pats::resources::SlotKind::LpAllocMsg,
                    TaskId(u64::MAX),
                );
                drop(plan);
                (cfg, st)
            },
            |(cfg, st)| {
                let mut plan = PlacementPlan::new(&st);
                let dur = st
                    .link_model
                    .slot_duration(&cfg, pats::resources::SlotKind::LpAllocMsg);
                plan.stage_link_earliest(
                    &st,
                    SimTime::ZERO,
                    dur,
                    pats::resources::SlotKind::LpAllocMsg,
                    TaskId(u64::MAX),
                );
                drop(plan);
            },
        );
        show(&mut results, r);

        let r = bench_with_setup(
            &format!("link_clone_floor/devices={devices}"),
            warmup,
            iters * 2,
            || loaded_state(devices),
            |(_cfg, st)| st.link().clone().len(),
        );
        show(&mut results, r);
    }

    match write_json("plan", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
