//! PJRT execution benchmarks: per-artifact latency and the horizontal
//! partitioning pipeline at each width (skipped when `make artifacts` has
//! not run).

use pats::bench::{bench, section};
use pats::runtime::{partition, Engine, Tensor};

fn main() {
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return;
    }
    let engine = match Engine::load(&dir) {
        Ok(engine) => engine,
        Err(e) => {
            eprintln!("SKIP: cannot load artifacts ({e}); build with --features xla");
            return;
        }
    };
    println!("platform {}, {} executables", engine.platform(), engine.names().count());

    let frame = Tensor::from_fn(&[48, 48, 3], |i| ((i * 2_654_435_761) % 1000) as f32 / 1000.0);
    let bg = Tensor::zeros(&[48, 48, 3]);

    section("single executables");
    let r = bench("detector", 3, 50, || {
        partition::run_detector(&engine, &frame, &bg).unwrap()
    });
    println!("{}", r.render());
    let r = bench("classifier", 3, 50, || {
        partition::run_classifier(&engine, &frame).unwrap()
    });
    println!("{}", r.render());
    let r = bench("cnn_full (monolithic)", 3, 20, || {
        engine.execute("cnn_full", &[&frame]).unwrap()
    });
    println!("{}", r.render());

    section("horizontal partitioning pipeline");
    for tiles in [1usize, 2, 4] {
        let r = bench(&format!("run_cnn/tiles={tiles}"), 2, 15, || {
            partition::run_cnn(&engine, &frame, tiles).unwrap()
        });
        println!("{}", r.render());
    }

    section("per-block tile executables");
    for block in 0..partition::NUM_BLOCKS {
        let spec = engine.spec(&format!("block{block}_tile4")).unwrap().clone();
        let tile = Tensor::zeros(&spec.input_shapes[0]);
        let name = format!("block{block}_tile4");
        let r = bench(&name, 3, 30, || engine.execute(&name, &[&tile]).unwrap());
        println!("{}", r.render());
    }
}
