//! Work-stealing executor benchmarks: the persistent pool
//! (`[sharding] workers`) against the per-batch `std::thread::scope`
//! spawn/join it replaces.
//!
//! Four claims are tracked across commits in `BENCH_executor.json`:
//!
//! * **Dispatch floor.** `pool_dispatch/*` vs `spawn_floor/*`: pushing a
//!   batch of empty jobs through the parked pool vs spawning and joining
//!   the same number of scoped OS threads — the fixed cost every sweep
//!   barrier pays, which is the executor's whole reason to exist.
//! * **Pooled sweep latency.** `sweep_pooled/*` vs `sweep_scoped/*`: the
//!   same 1024-device `lp_sweep` decision batch with the pool armed vs
//!   the historical scoped-thread path, at growing shard counts.
//! * **Steal balance on skewed batches.** `skewed_jobs/*`: one batch
//!   whose job costs are heavily skewed; thieves drain the long tail, so
//!   wall clock should track total-work/workers, not the largest job
//!   chain on one deque.
//! * **Parallel candidate-plan search.** `rescue_serial` vs
//!   `rescue_pooled`: a device failure whose high-priority orphan forces
//!   a full top-K eviction-candidate search on a saturated fleet — the
//!   nested fan-out path (`rescue::relocate_hp` through
//!   `executor::current()`).

use pats::bench::{bench_with_setup, section, smoke, write_json, BenchResult};
use pats::config::{SystemConfig, WorkerCount};
use pats::coordinator::ControlSurface;
use pats::scheduler::PatsScheduler;
use pats::shard::{ControlPlane, LpJob};
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;
use pats::util::executor::{Executor, Job};

fn plane_and_jobs(
    devices: usize,
    shards: usize,
    workers: WorkerCount,
) -> (ControlPlane<PatsScheduler>, Vec<Vec<LpJob>>) {
    let mut cfg = SystemConfig::default();
    cfg.devices = devices;
    cfg.sharding.shards = shards;
    cfg.sharding.workers = workers;
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let deadline = SimTime::ZERO + cfg.frame_deadline();
    let mut jobs = vec![Vec::new(); shards];
    for d in 0..devices as u32 {
        jobs[plane.home_shard(DeviceId(d))].push(LpJob {
            frame: FrameId(d as u64),
            source: DeviceId(d),
            n: 2,
            deadline,
            now: SimTime::ZERO,
        });
    }
    (plane, jobs)
}

/// A plane on a saturated fleet with one allocated high-priority task on
/// device 0: crashing device 0 forces the rescue relocation through the
/// full top-K eviction-candidate search (every surviving device is busy).
fn crash_fixture(
    devices: usize,
    workers: WorkerCount,
) -> (ControlPlane<PatsScheduler>, SimTime) {
    let (mut plane, jobs) = plane_and_jobs(devices, 1, workers);
    // Two 2-task admissions per device fill the 4-core devices.
    plane.lp_sweep(&jobs, false);
    let deadline = SimTime::ZERO + SystemConfig::default().frame_deadline();
    for d in 0..devices as u32 {
        plane.handle_lp_request(FrameId(10_000 + d as u64), DeviceId(d), 2, deadline, SimTime::ZERO);
    }
    plane.handle_hp_request(FrameId(20_000), DeviceId(0), SimTime::ZERO);
    (plane, SimTime::from_secs_f64(0.5))
}

/// Deterministic spin so skewed job costs are comparable across runs.
fn spin(units: u64) -> u64 {
    let mut acc = 0u64;
    for i in 0..units * 1_000 {
        acc = acc.wrapping_mul(6_364_136_223_846_793_005).wrapping_add(i);
        std::hint::black_box(acc);
    }
    acc
}

fn show(results: &mut Vec<BenchResult>, r: BenchResult) {
    println!("{}", r.render());
    results.push(r);
}

fn main() {
    let mut results: Vec<BenchResult> = Vec::new();
    let devices = if smoke() { 256 } else { 1024 };
    let iters = if smoke() { 3 } else { 8 };
    let micro_iters = if smoke() { 10 } else { 50 };

    section("dispatch floor: parked pool vs scoped spawn/join");
    for &jobs_n in &[4usize, 16] {
        let r = bench_with_setup(
            &format!("spawn_floor/jobs={jobs_n}"),
            1,
            micro_iters,
            || (),
            |()| {
                std::thread::scope(|scope| {
                    let handles: Vec<_> = (0..jobs_n)
                        .map(|i| scope.spawn(move || std::hint::black_box(i)))
                        .collect();
                    handles.into_iter().map(|h| h.join().unwrap()).sum::<usize>()
                })
            },
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("pool_dispatch/jobs={jobs_n}/workers=4"),
            1,
            micro_iters,
            || Executor::new(4),
            |pool| {
                let jobs: Vec<Job<'_>> = (0..jobs_n)
                    .map(|i| -> Job<'_> {
                        Box::new(move || {
                            std::hint::black_box(i);
                        })
                    })
                    .collect();
                pool.run(jobs);
            },
        );
        show(&mut results, r);
    }

    section("end-to-end decision sweep: scoped threads vs pooled workers");
    for &k in &[2usize, 4, 8] {
        let r = bench_with_setup(
            &format!("sweep_scoped/devices={devices}/shards={k}"),
            1,
            iters,
            || plane_and_jobs(devices, k, WorkerCount::Off),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, true).len(),
        );
        show(&mut results, r);
        let r = bench_with_setup(
            &format!("sweep_pooled/devices={devices}/shards={k}/workers={k}"),
            1,
            iters,
            || plane_and_jobs(devices, k, WorkerCount::Fixed(k)),
            |(mut plane, jobs)| plane.lp_sweep(&jobs, true).len(),
        );
        show(&mut results, r);
    }

    section("steal balance: heavily skewed job costs");
    for &w in &[1usize, 4] {
        let r = bench_with_setup(
            &format!("skewed_jobs/workers={w}"),
            1,
            micro_iters,
            || Executor::new(w),
            |pool| {
                // 1 giant + 63 small jobs: with thieves the small tail
                // drains in parallel with the giant.
                let jobs: Vec<Job<'_>> = (0..64)
                    .map(|i| -> Job<'_> {
                        let units = if i == 0 { 64 } else { 1 };
                        Box::new(move || {
                            std::hint::black_box(spin(units));
                        })
                    })
                    .collect();
                pool.run(jobs);
            },
        );
        show(&mut results, r);
    }

    section("rescue candidate-plan search: serial vs pooled fan-out");
    let rescue_devices = if smoke() { 16 } else { 48 };
    let rescue_iters = if smoke() { 3 } else { 10 };
    let r = bench_with_setup(
        "rescue_serial",
        1,
        rescue_iters,
        || crash_fixture(rescue_devices, WorkerCount::Off),
        |(mut plane, now)| plane.handle_device_failure(DeviceId(0), now).hp_rescued.len(),
    );
    show(&mut results, r);
    let r = bench_with_setup(
        "rescue_pooled/workers=4",
        1,
        rescue_iters,
        || crash_fixture(rescue_devices, WorkerCount::Fixed(4)),
        |(mut plane, now)| plane.handle_device_failure(DeviceId(0), now).hp_rescued.len(),
    );
    show(&mut results, r);

    match write_json("executor", &results) {
        Ok(path) => println!("\nwrote {}", path.display()),
        Err(e) => eprintln!("\ncould not write bench JSON: {e}"),
    }
}
