//! Network-dynamics benchmark: the four-policy churn sweep at the default
//! `[dynamics]` scale (256 devices, 50 % crash, link degradation episode),
//! timed, with the full orphan-rescue census recorded to
//! `BENCH_dynamics.json`. `cargo bench --bench dynamics` is the release-mode
//! run behind the acceptance claim that the preemption-aware scheduler
//! rescues more orphaned high-priority tasks than the no-preemption
//! baseline.

use pats::config::SystemConfig;
use pats::experiments::{dynamics, dynamics_json, dynamics_table};
use pats::util::json::Json;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "running the churn sweep: {} devices × {} cycles, {}% crash / {}% drain \
         (seed {:#x}) ...",
        cfg.dynamics.devices,
        cfg.dynamics.cycles,
        cfg.dynamics.crash_pct,
        cfg.dynamics.drain_pct,
        cfg.seed
    );
    let t0 = std::time::Instant::now();
    let rows = dynamics(&cfg);
    let wall = t0.elapsed();
    println!("sweep complete in {wall:.2?}\n");
    println!("{}", dynamics_table(&rows));

    let rescued = |label: &str| {
        rows.iter()
            .find(|r| r.label == label)
            .map(|r| r.metrics.hp_rescued)
            .unwrap_or(0)
    };
    println!(
        "HP orphans rescued: preemption-aware {} vs no-preemption {}",
        rescued("DYN_PS"),
        rescued("DYN_NPS")
    );

    let doc = Json::obj()
        .with("bench", "dynamics")
        .with("sweep_wall_ms", wall.as_secs_f64() * 1_000.0)
        .with("sweep", dynamics_json(&rows));
    match std::fs::write("BENCH_dynamics.json", doc.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_dynamics.json"),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
