//! End-to-end experiment benchmark: every paper scenario at full scale
//! (5184 device-frames), timed, followed by the complete figure/table
//! report and a fleet-size sweep (4/64/256/1024 devices). `cargo bench
//! --bench experiments` regenerates the paper's evaluation in one shot and
//! records the costs to `BENCH_experiments.json`.

use pats::config::SystemConfig;
use pats::experiments::{fleet_scale, fleet_scale_json, fleet_scale_table, ExperimentSet};
use pats::util::json::Json;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "running the full scenario matrix at {} device-frames (seed {:#x}) ...",
        cfg.frames, cfg.seed
    );
    let t0 = std::time::Instant::now();
    let set = ExperimentSet::run(&cfg);
    let matrix_wall = t0.elapsed();
    println!("matrix complete in {matrix_wall:.2?}\n");
    println!("{}", set.render_all());

    // Fleet sweep: the same scheduler from the paper's 4 devices up to a
    // 1024-device fleet, under the configured arrival pattern.
    let sizes = cfg.fleet.sweep_sizes.clone();
    println!(
        "\nrunning the fleet sweep at {sizes:?} devices × {} cycles ({} pattern) ...",
        cfg.fleet.cycles,
        cfg.fleet.pattern.name()
    );
    let t1 = std::time::Instant::now();
    let rows = fleet_scale(&cfg, &sizes);
    println!("fleet sweep complete in {:.2?}\n", t1.elapsed());
    println!("{}", fleet_scale_table(&rows));

    let doc = Json::obj()
        .with("bench", "experiments")
        .with("matrix_wall_ms", matrix_wall.as_secs_f64() * 1_000.0)
        .with("fleet", fleet_scale_json(&rows));
    match std::fs::write("BENCH_experiments.json", doc.to_string_pretty()) {
        Ok(()) => println!("wrote BENCH_experiments.json"),
        Err(e) => eprintln!("could not write bench JSON: {e}"),
    }
}
