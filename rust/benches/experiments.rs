//! End-to-end experiment benchmark: every paper scenario at full scale
//! (5184 device-frames), timed, followed by the complete figure/table
//! report. `cargo bench --bench experiments` regenerates the paper's
//! evaluation in one shot.

use pats::config::SystemConfig;
use pats::experiments::ExperimentSet;

fn main() {
    let cfg = SystemConfig::default();
    println!(
        "running the full scenario matrix at {} device-frames (seed {:#x}) ...",
        cfg.frames, cfg.seed
    );
    let t0 = std::time::Instant::now();
    let mut set = ExperimentSet::run(&cfg);
    println!("matrix complete in {:.2?}\n", t0.elapsed());
    println!("{}", set.render_all());
}
