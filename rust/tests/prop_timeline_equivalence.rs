//! The gap-indexed [`Timeline`] must be behaviour-identical to the seed's
//! linear implementation.
//!
//! [`LinearCalendar`] below re-implements the seed's sorted-`Vec` timeline
//! verbatim (linear gap scan in `earliest_fit`, neighbour checks in
//! `reserve`, `retain`-based removal). Random op sequences are applied to
//! both structures in lockstep; after every operation the observable state
//! (slot list, lengths, fit answers, busy time) must agree exactly, and the
//! gap index's internal invariants must hold.

use pats::resources::{SlotKind, Timeline};
use pats::task::{TaskId, Window};
use pats::time::{SimDuration, SimTime};
use pats::util::prop::{run, Gen};

/// The seed's linear timeline, kept as the behavioural oracle.
#[derive(Debug, Clone, Default)]
struct LinearCalendar {
    /// (window, owner), sorted by start, pairwise non-overlapping.
    slots: Vec<(Window, TaskId)>,
}

impl LinearCalendar {
    fn first_ending_after(&self, t: SimTime) -> usize {
        self.slots.partition_point(|s| s.0.end <= t)
    }

    fn earliest_fit(&self, not_before: SimTime, dur: SimDuration) -> SimTime {
        let mut candidate = not_before;
        for (window, _) in &self.slots[self.first_ending_after(not_before)..] {
            let needed_end = candidate + dur;
            if needed_end <= window.start {
                return candidate;
            }
            candidate = candidate.max(window.end);
        }
        candidate
    }

    fn reserve(&mut self, start: SimTime, dur: SimDuration, owner: TaskId) -> bool {
        let window = Window::from_duration(start, dur);
        let idx = self.slots.partition_point(|s| s.0.start < window.start);
        if idx > 0 && self.slots[idx - 1].0.overlaps(&window) {
            return false;
        }
        if idx < self.slots.len() && self.slots[idx].0.overlaps(&window) {
            return false;
        }
        self.slots.insert(idx, (window, owner));
        true
    }

    fn remove_owner(&mut self, owner: TaskId) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.1 != owner);
        before - self.slots.len()
    }

    fn remove_owner_from(&mut self, owner: TaskId, t: SimTime) -> usize {
        let before = self.slots.len();
        self.slots.retain(|s| s.1 != owner || s.0.start < t);
        before - self.slots.len()
    }

    fn prune_before(&mut self, t: SimTime) -> usize {
        let cut = self.first_ending_after(t);
        self.slots.drain(..cut).count()
    }

    fn busy_time_in(&self, window: &Window) -> SimDuration {
        let mut total = SimDuration::ZERO;
        for (w, _) in &self.slots {
            if w.overlaps(window) {
                let lo = w.start.max(window.start);
                let hi = w.end.min(window.end);
                total = total + hi.since(lo);
            }
        }
        total
    }
}

fn assert_same_state(tl: &Timeline, model: &LinearCalendar, ctx: &str) {
    tl.check_invariants().unwrap();
    assert_eq!(tl.len(), model.slots.len(), "{ctx}: slot counts diverge");
    let got: Vec<(Window, TaskId)> =
        tl.slots().iter().map(|s| (s.window, s.owner)).collect();
    assert_eq!(got, model.slots, "{ctx}: slot lists diverge");
}

fn t_us(g: &mut Gen) -> SimTime {
    SimTime::from_micros(g.u64(0, 100_000))
}

fn d_us(g: &mut Gen) -> SimDuration {
    SimDuration::from_micros(g.u64(1, 10_000))
}

#[test]
fn gap_index_matches_linear_scan_on_random_workloads() {
    run("timeline equivalence", 250, |g| {
        let mut tl = Timeline::new();
        let mut model = LinearCalendar::default();
        let mut owners: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 70) {
            match g.usize(0, 5) {
                // reserve_earliest: both must pick the same window.
                0 | 1 => {
                    let owner = TaskId(step as u64);
                    let not_before = t_us(g);
                    let dur = d_us(g);
                    let w = tl.reserve_earliest(not_before, dur, SlotKind::PollMsg, owner);
                    let want = model.earliest_fit(not_before, dur);
                    assert_eq!(w.start, want, "earliest_fit diverges at step {step}");
                    assert!(model.reserve(want, dur, owner), "oracle rejects its own fit");
                    owners.push(owner);
                }
                // explicit reserve: success/failure parity.
                2 => {
                    let owner = TaskId(1_000_000 + step as u64);
                    let start = t_us(g);
                    let dur = d_us(g);
                    let got = tl.reserve(start, dur, SlotKind::StateUpdate, owner).is_ok();
                    let want = model.reserve(start, dur, owner);
                    assert_eq!(got, want, "reserve parity at step {step}");
                    if got {
                        owners.push(owner);
                    }
                }
                // remove one owner entirely.
                3 => {
                    if !owners.is_empty() {
                        let idx = g.usize(0, owners.len() - 1);
                        let owner = owners.swap_remove(idx);
                        assert_eq!(tl.remove_owner(owner), model.remove_owner(owner));
                    }
                }
                // remove one owner's future slots only.
                4 => {
                    if !owners.is_empty() {
                        let idx = g.usize(0, owners.len() - 1);
                        let owner = owners[idx];
                        let cut = t_us(g);
                        assert_eq!(
                            tl.remove_owner_from(owner, cut),
                            model.remove_owner_from(owner, cut)
                        );
                    }
                }
                // compact history.
                _ => {
                    let cut = t_us(g);
                    assert_eq!(tl.prune_before(cut), model.prune_before(cut));
                }
            }
            assert_same_state(&tl, &model, &format!("after step {step}"));

            // Read-only probes against the oracle at every step.
            let nb = t_us(g);
            let dur = d_us(g);
            assert_eq!(
                tl.earliest_fit(nb, dur),
                model.earliest_fit(nb, dur),
                "fit probe diverges at step {step}"
            );
            assert_eq!(
                tl.earliest_fit(nb, SimDuration::ZERO),
                model.earliest_fit(nb, SimDuration::ZERO),
                "zero-duration fit probe diverges at step {step}"
            );
            let a = t_us(g);
            let b = SimTime::from_micros(a.as_micros() + g.u64(0, 50_000));
            let probe = Window::new(a, b);
            assert_eq!(
                tl.busy_time_in(&probe),
                model.busy_time_in(&probe),
                "busy probe diverges at step {step}"
            );
            assert_eq!(
                tl.overlapping(&probe).count(),
                model
                    .slots
                    .iter()
                    .filter(|(w, _)| w.overlaps(&probe))
                    .count(),
                "overlap probe diverges at step {step}"
            );
        }
    });
}

#[test]
fn gap_index_matches_linear_scan_on_dense_calendars() {
    // Densely packed, regular calendars hit different paths than random
    // ones: exact-fill reserves (gap fully consumed), touching slots, and
    // fits that must skip long runs of equal-length gaps.
    run("dense equivalence", 60, |g| {
        let mut tl = Timeline::new();
        let mut model = LinearCalendar::default();
        let pitch = g.u64(2, 50) * 100;
        let slot_len = g.u64(1, pitch / 100) * 100;
        for i in 0..200u64 {
            let start = SimTime::from_micros(i * pitch);
            let dur = SimDuration::from_micros(slot_len);
            tl.reserve(start, dur, SlotKind::HpAllocMsg, TaskId(i)).unwrap();
            assert!(model.reserve(start, dur, TaskId(i)));
        }
        for _ in 0..40 {
            let nb = SimTime::from_micros(g.u64(0, 220 * pitch));
            let dur = SimDuration::from_micros(g.u64(1, 2 * pitch));
            assert_eq!(tl.earliest_fit(nb, dur), model.earliest_fit(nb, dur));
        }
        // Exact-fill: reserve a whole interior gap, then free it again.
        if slot_len < pitch {
            let gap_start = SimTime::from_micros(slot_len);
            let gap_len = SimDuration::from_micros(pitch - slot_len);
            tl.reserve(gap_start, gap_len, SlotKind::PollMsg, TaskId(999)).unwrap();
            assert!(model.reserve(gap_start, gap_len, TaskId(999)));
            assert_same_state(&tl, &model, "exact fill");
            assert_eq!(tl.remove_owner(TaskId(999)), 1);
            assert_eq!(model.remove_owner(TaskId(999)), 1);
            assert_same_state(&tl, &model, "exact free");
        }
    });
}
