//! Network-dynamics integration: churn scenarios are deterministic, a
//! crashed device's tasks are rescued or counted lost (never silently
//! dropped), and failure detection reclaims every reservation the dead
//! device held.

use pats::config::SystemConfig;
use pats::coordinator::Controller;
use pats::metrics::ScenarioMetrics;
use pats::scheduler::PatsScheduler;
use pats::sim::run_scenario_dynamic;
use pats::task::{DeviceId, FrameId, TaskState};
use pats::time::{SimDuration, SimTime};
use pats::trace::{ChurnEvent, ChurnScript, FleetPattern, FleetProfile, Trace};

fn conserved(m: &ScenarioMetrics) {
    assert_eq!(
        m.hp_completed + m.hp_failed_alloc + m.hp_violated + m.hp_lost_churn,
        m.hp_generated,
        "HP conservation under churn"
    );
    assert_eq!(
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated
            + m.lp_lost_churn,
        m.lp_generated,
        "LP conservation under churn"
    );
    assert_eq!(m.hp_orphaned, m.hp_rescued + m.hp_lost_churn);
    assert_eq!(m.lp_orphaned, m.lp_rescued + m.lp_requeued_churn + m.lp_lost_churn);
    assert_eq!(
        m.frames_completed + m.frames_failed_hp + m.frames_failed_lp + m.frames_lost_churn,
        m.frames_total
    );
}

#[test]
fn seeded_churn_scenario_is_deterministic() {
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 48; // 3 cycles over 16 devices
    cfg.dynamics.detect_delay_s = 0.5;
    let profile = FleetProfile {
        pattern: FleetPattern::Steady,
        hp_only_pct: 20,
        lp_weight: 2,
    };
    let trace = Trace::generate_fleet(&profile, 16, 3, cfg.seed);
    let churn = pats::trace::ChurnProfile {
        crash_pct: 25,
        drain_pct: 12,
        rejoin_after_s: 0.0,
        churn_start_s: 5.0,
        churn_end_s: 40.0,
        degrade_factor: 0.8,
        degrade_start_s: 10.0,
        degrade_end_s: 30.0,
    };
    let script = ChurnScript::generate(&churn, 16, cfg.seed);
    assert!(script.crashes() > 0);
    let a = run_scenario_dynamic(&cfg, &trace, &script, "churn-a").metrics;
    let b = run_scenario_dynamic(&cfg, &trace, &script, "churn-b").metrics;
    for (x, y) in [
        (a.frames_completed, b.frames_completed),
        (a.frames_lost_churn, b.frames_lost_churn),
        (a.hp_generated, b.hp_generated),
        (a.hp_completed, b.hp_completed),
        (a.hp_orphaned, b.hp_orphaned),
        (a.hp_rescued, b.hp_rescued),
        (a.hp_lost_churn, b.hp_lost_churn),
        (a.lp_generated, b.lp_generated),
        (a.lp_completed, b.lp_completed),
        (a.lp_orphaned, b.lp_orphaned),
        (a.lp_lost_churn, b.lp_lost_churn),
        (a.preemptions, b.preemptions),
        (a.devices_crashed, b.devices_crashed),
        (a.devices_drained, b.devices_drained),
    ] {
        assert_eq!(x, y, "counter differs between identical seeded runs");
    }
    conserved(&a);
}

/// A perfectly synchronised single-cycle scenario puts one HP task in
/// flight on every device; crashing device 0 mid-window orphans exactly
/// that task, and the idle survivors adopt it: the crashed device's HP task
/// completes elsewhere — or is counted lost — never silently dropped.
#[test]
fn crashed_devices_hp_task_is_rescued_or_counted_lost() {
    let mut cfg = SystemConfig::default();
    cfg.frames = 4;
    cfg.staggered_pairs = false;
    cfg.max_start_offset_s = 0.0;
    cfg.max_clock_skew = SimDuration::ZERO;
    cfg.hp_deadline_s = 4.0; // leave room for detection + relocation
    cfg.dynamics.detect_delay_s = 0.3;
    let trace = Trace::parse("0 0 0 0\n").unwrap(); // HP-only, one cycle
    let script = ChurnScript::from_events(vec![(
        SimTime::from_secs_f64(0.5),
        ChurnEvent::Crash(DeviceId(0)),
    )]);
    let m = run_scenario_dynamic(&cfg, &trace, &script, "hp-rescue").metrics;
    assert_eq!(m.hp_generated, 4);
    assert_eq!(m.devices_crashed, 1);
    assert_eq!(m.failures_detected, 1);
    assert_eq!(m.hp_orphaned, 1, "exactly the crashed device's stage-2 task");
    assert_eq!(m.hp_rescued, 1, "three idle survivors: the orphan relocates");
    assert_eq!(m.hp_lost_churn, 0);
    conserved(&m);

    // With detection arriving after the paper's tight deadline, the same
    // orphan is unsalvageable — and still fully accounted.
    cfg.hp_deadline_s = 1.5;
    let m = run_scenario_dynamic(&cfg, &trace, &script, "hp-lost").metrics;
    assert_eq!(m.hp_orphaned, 1);
    assert_eq!(m.hp_rescued, 0, "1.5 s deadline minus detection leaves no room");
    assert_eq!(m.hp_lost_churn, 1);
    conserved(&m);
}

/// Controller-level reclamation property: after failure detection, no core
/// slot on the dead device survives, and no orphan owns a future link slot.
#[test]
fn failure_detection_reclaims_every_dead_reservation() {
    let mut cfg = SystemConfig::default();
    cfg.hp_deadline_s = 4.0;
    let policy = PatsScheduler::from_config(&cfg);
    let mut c = Controller::new(cfg, policy);

    // Load the network: one HP task per device, then a 4-task DNN set from
    // device 0 so offloads land across the network.
    for d in 0..4u32 {
        let (_, _, out) = c.handle_hp_request(FrameId(d as u64), DeviceId(d), SimTime::ZERO);
        assert!(out.allocated());
    }
    let deadline = SimTime::from_secs_f64(18.86);
    let (_, _, lp_out) =
        c.handle_lp_request(FrameId(0), DeviceId(0), 4, deadline, SimTime::from_millis(10));
    assert!(lp_out.fully_allocated());
    let victims: Vec<_> = lp_out
        .placements
        .iter()
        .filter(|p| p.device == DeviceId(1))
        .map(|p| p.task)
        .collect();

    let detect_at = SimTime::from_secs_f64(0.5);
    let outcome = c.handle_device_failure(DeviceId(1), detect_at);
    assert!(outcome.total() >= 1 + victims.len(), "HP + hosted LP tasks orphaned");

    // 1. The dead device's core calendar is empty and stays unschedulable.
    assert_eq!(c.state.device(DeviceId(1)).len(), 0);
    assert!(!c.state.device_is_up(DeviceId(1)));

    // 2. No surviving timeline slot — core or link — is owned by a task
    //    that is (terminally) lost to the device failure.
    for rec in c.state.tasks() {
        if rec.state == TaskState::Failed(pats::task::FailReason::DeviceLost) {
            let id = rec.spec.id;
            for d in 0..4u32 {
                assert!(
                    c.state.device(DeviceId(d)).slots().iter().all(|s| s.task != id),
                    "lost orphan {id:?} still holds cores on dev{d}"
                );
            }
            assert!(
                c.state
                    .link()
                    .slots()
                    .iter()
                    .all(|s| s.owner != id || s.window.start < detect_at),
                "lost orphan {id:?} still owns future link slots"
            );
        }
    }

    // 3. Rescued orphans hold reservations only on live devices.
    for rescue in &outcome.hp_rescued {
        assert_ne!(rescue.device, DeviceId(1));
    }
    for p in &outcome.lp_rescued {
        assert_ne!(p.device, DeviceId(1));
    }
    c.state.check_invariants().unwrap();
}

/// The preemption-aware scheduler rescues orphans a no-preemption run must
/// lose: on a saturated network a rescue needs an eviction.
#[test]
fn preemption_rescues_strictly_more_on_a_saturated_network() {
    let run = |preemption: bool| {
        let mut cfg = SystemConfig::default();
        cfg.devices = 3;
        cfg.hp_deadline_s = 5.0;
        cfg.preemption = preemption;
        let policy = PatsScheduler::from_config(&cfg);
        let mut c = Controller::new(cfg, policy);
        // Device 0 hosts an HP task; devices 1 and 2 are saturated with
        // preemptible DNN work (two 2-core tasks each).
        let (_, _, out) = c.handle_hp_request(FrameId(0), DeviceId(0), SimTime::ZERO);
        assert!(out.allocated());
        let deadline = SimTime::from_secs_f64(30.0);
        for d in 1..3u32 {
            let (_, _, lp) = c.handle_lp_request(
                FrameId(d as u64),
                DeviceId(d),
                2,
                deadline,
                SimTime::from_millis(5),
            );
            assert!(lp.fully_allocated());
        }
        c.handle_device_failure(DeviceId(0), SimTime::from_secs_f64(0.5))
    };
    let with = run(true);
    let without = run(false);
    assert_eq!(with.hp_rescued.len(), 1, "eviction frees a core for the orphan");
    assert!(with.lost.is_empty());
    assert_eq!(without.hp_rescued.len(), 0, "no free core, no eviction allowed");
    assert_eq!(without.lost.len(), 1);
    assert!(
        with.hp_rescued.len() > without.hp_rescued.len(),
        "preemption-aware rescue strictly dominates"
    );
}
