//! Integration: full simulated scenarios at moderate scale, checking the
//! paper's qualitative findings (§1) hold as *shapes*, plus accounting
//! conservation and determinism across every policy.

use pats::config::{BandwidthEstimator, Policy as PolicyKind, SystemConfig};
use pats::metrics::ScenarioMetrics;
use pats::sim::run_scenario;
use pats::trace::{Distribution, Trace};

fn run(cfg: &SystemConfig, dist: Distribution, label: &str) -> ScenarioMetrics {
    let trace = Trace::generate(dist, cfg.devices, cfg.frames, cfg.seed);
    run_scenario(cfg, &trace, label).metrics
}

fn mid_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.frames = 1296; // quarter of the paper scale: fast but stable
    cfg
}

#[test]
fn finding1_preemption_increases_frames_and_hp() {
    // "Preemption leads to an overall increase in processed frames
    //  end-to-end" + "Preemption allows 10-23% more high-priority tasks to
    //  complete ... resulting in a 99% completion rate".
    let mut cfg = mid_cfg();
    cfg.preemption = true;
    let with = run(&cfg, Distribution::Uniform, "UPS");
    cfg.preemption = false;
    let without = run(&cfg, Distribution::Uniform, "UNPS");

    assert!(
        with.hp_completion_pct() > 97.0,
        "preemption HP completion {:.2} must be ~99%",
        with.hp_completion_pct()
    );
    let hp_gain = with.hp_completion_pct() - without.hp_completion_pct();
    assert!(
        (8.0..=30.0).contains(&hp_gain),
        "HP gain {hp_gain:.2} outside the paper's 10-23pp band (±tolerance)"
    );
    assert!(
        with.frames_completed > without.frames_completed,
        "preemption must net frame completions: {} vs {}",
        with.frames_completed,
        without.frames_completed
    );
}

#[test]
fn finding2_preemption_costs_lp_set_completion() {
    // "The cost of preemption leads to ... less DNN tasks completing in
    //  each late stage pipeline" (per-request completion drops).
    let mut cfg = mid_cfg();
    cfg.preemption = true;
    let with = run(&cfg, Distribution::Uniform, "UPS");
    cfg.preemption = false;
    let without = run(&cfg, Distribution::Uniform, "UNPS");
    assert!(
        with.lp_per_request_pct() < without.lp_per_request_pct(),
        "preemption per-request {:.2} must be below non-preemption {:.2}",
        with.lp_per_request_pct(),
        without.lp_per_request_pct()
    );
    // ... while GENERATING far more low-priority tasks (Table 2's shape).
    assert!(
        with.lp_generated as f64 > without.lp_generated as f64 * 1.1,
        "preemption generates more LP: {} vs {}",
        with.lp_generated,
        without.lp_generated
    );
}

#[test]
fn finding3_scheduler_beats_workstealers() {
    // "Schedulers outperform workstealers in processing constrained
    //  pipeline applications under preemption conditions."
    let mut cfg = mid_cfg();
    cfg.preemption = true;
    cfg.policy = PolicyKind::Scheduler;
    let sched = run(&cfg, Distribution::Weighted(4), "WPS_4");
    for policy in [PolicyKind::CentralWorkstealer, PolicyKind::DecentralWorkstealer] {
        cfg.policy = policy;
        let ws = run(&cfg, Distribution::Weighted(4), "ws");
        assert!(
            sched.frame_completion_pct() > ws.frame_completion_pct() + 3.0,
            "{policy:?}: scheduler {:.2}% must clearly beat stealer {:.2}%",
            sched.frame_completion_pct(),
            ws.frame_completion_pct()
        );
    }
}

#[test]
fn finding4_reallocation_rarely_succeeds() {
    // Table 3: "when preemption occurs, it is extremely unlikely that the
    // task will receive reallocation successfully."
    let cfg = mid_cfg();
    let m = run(&cfg, Distribution::Weighted(4), "WPS_4");
    assert!(m.preemptions > 20, "weighted-4 must preempt ({})", m.preemptions);
    let rate = m.realloc_success as f64 / m.preemptions as f64;
    assert!(rate < 0.05, "reallocation success rate {rate:.3} must be near zero");
}

#[test]
fn finding5_four_core_tasks_preempted_most() {
    // Fig 7: "a task is more likely to experience preemption when it fully
    // occupies the resources of a device" — per-capita, 4-core allocations
    // are preempted at a higher rate than 2-core ones.
    let cfg = mid_cfg();
    let m = run(&cfg, Distribution::Uniform, "UPS");
    let pre2 = *m.preempted_by_cores.get(&2).unwrap_or(&0) as f64;
    let pre4 = *m.preempted_by_cores.get(&4).unwrap_or(&0) as f64;
    let alloc2 = (m.core_alloc_local.get(&2).unwrap_or(&0)
        + m.core_alloc_offloaded.get(&2).unwrap_or(&0)) as f64;
    let alloc4 = (m.core_alloc_local.get(&4).unwrap_or(&0)
        + m.core_alloc_offloaded.get(&4).unwrap_or(&0)) as f64;
    assert!(alloc2 > 0.0 && alloc4 > 0.0);
    let rate2 = pre2 / alloc2;
    let rate4 = pre4 / alloc4;
    assert!(
        rate4 > rate2,
        "4-core preemption rate {rate4:.4} must exceed 2-core {rate2:.4}"
    );
}

#[test]
fn load_increase_degrades_completion() {
    // Fig 2b: completion is ~flat W1→W2 then drops through W3/W4.
    let cfg = mid_cfg();
    let w1 = run(&cfg, Distribution::Weighted(1), "W1").frame_completion_pct();
    let w3 = run(&cfg, Distribution::Weighted(3), "W3").frame_completion_pct();
    let w4 = run(&cfg, Distribution::Weighted(4), "W4").frame_completion_pct();
    assert!(w1 > w3 && w3 > w4, "monotone degradation: {w1:.1} {w3:.1} {w4:.1}");
}

#[test]
fn accounting_conserves_tasks_all_policies() {
    let mut cfg = mid_cfg();
    cfg.frames = 400;
    for policy in [
        PolicyKind::Scheduler,
        PolicyKind::CentralWorkstealer,
        PolicyKind::DecentralWorkstealer,
    ] {
        for preemption in [true, false] {
            cfg.policy = policy;
            cfg.preemption = preemption;
            let m = run(&cfg, Distribution::Weighted(4), "x");
            let accounted =
                m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated;
            assert_eq!(accounted, m.lp_generated, "{policy:?}/preempt={preemption}");
            let hp_accounted = m.hp_completed + m.hp_failed_alloc + m.hp_violated;
            assert_eq!(hp_accounted, m.hp_generated, "{policy:?}/preempt={preemption}");
            assert!(m.frames_completed <= m.frames_total);
        }
    }
}

#[test]
fn seeds_reproduce_and_differ() {
    let mut cfg = mid_cfg();
    cfg.frames = 400;
    let a = run(&cfg, Distribution::Uniform, "a");
    let b = run(&cfg, Distribution::Uniform, "b");
    assert_eq!(a.frames_completed, b.frames_completed);
    assert_eq!(a.preemptions, b.preemptions);
    cfg.seed ^= 0xDEAD;
    let c = run(&cfg, Distribution::Uniform, "c");
    assert_ne!(
        (a.frames_completed, a.lp_completed),
        (c.frames_completed, c.lp_completed),
        "different seed must perturb results"
    );
}

#[test]
fn bandwidth_estimator_ablation_comparable() {
    // §7.3: EMA vs static throughput estimation are comparable.
    let mut cfg = mid_cfg();
    cfg.frames = 800;
    cfg.bandwidth_estimator = BandwidthEstimator::Static;
    let s = run(&cfg, Distribution::Weighted(3), "static");
    cfg.bandwidth_estimator = BandwidthEstimator::Ema;
    let e = run(&cfg, Distribution::Weighted(3), "ema");
    let delta = (s.frame_completion_pct() - e.frame_completion_pct()).abs();
    assert!(delta < 8.0, "estimators must be comparable (Δ {delta:.2}pp)");
}

#[test]
fn no_preemption_scenarios_never_preempt() {
    let mut cfg = mid_cfg();
    cfg.frames = 400;
    cfg.preemption = false;
    for policy in [
        PolicyKind::Scheduler,
        PolicyKind::CentralWorkstealer,
        PolicyKind::DecentralWorkstealer,
    ] {
        cfg.policy = policy;
        let m = run(&cfg, Distribution::Weighted(4), "np");
        assert_eq!(m.preemptions, 0, "{policy:?}");
        assert_eq!(m.lp_failed_preempted, 0, "{policy:?}");
        assert_eq!(m.hp_completed_via_preemption, 0, "{policy:?}");
    }
}
