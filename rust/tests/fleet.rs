//! Fleet-scale integration: large-fleet scenarios run to completion, stay
//! internally consistent, and are deterministic under a fixed seed.

use pats::config::SystemConfig;
use pats::experiments::{fleet_scale, fleet_scale_table};
use pats::metrics::ScenarioMetrics;
use pats::sim::run_scenario;
use pats::trace::{FleetPattern, FleetProfile, Trace};

fn lp_accounted(m: &ScenarioMetrics) {
    let accounted = m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated;
    assert_eq!(accounted, m.lp_generated, "every LP task needs a terminal account");
}

#[test]
fn fleet_sweep_runs_each_size_to_completion() {
    let mut cfg = SystemConfig::default();
    cfg.fleet.cycles = 2;
    let rows = fleet_scale(&cfg, &[4, 32, 64]);
    assert_eq!(rows.len(), 3);
    for row in &rows {
        assert_eq!(row.metrics.frames_total, (row.devices * 2) as u64);
        assert!(row.metrics.hp_generated > 0, "{} devices: no HP load", row.devices);
        lp_accounted(&row.metrics);
    }
    let table = fleet_scale_table(&rows);
    for needle in ["| 4 |", "| 32 |", "| 64 |"] {
        assert!(table.contains(needle), "missing row {needle}");
    }
}

#[test]
fn fleet_256_devices_is_deterministic() {
    let mut cfg = SystemConfig::default();
    cfg.devices = 256;
    cfg.fleet.cycles = 2;
    cfg.frames = 512;
    // A moderate mix keeps the debug-build test quick while still exercising
    // offloads and contention at 256 devices.
    let profile = FleetProfile {
        pattern: FleetPattern::Diurnal { period_cycles: 16 },
        hp_only_pct: 50,
        lp_weight: 1,
    };
    let trace = Trace::generate_fleet(&profile, 256, 2, cfg.seed);
    assert_eq!(trace.devices(), 256);
    let a = run_scenario(&cfg, &trace, "fleet-256-a").metrics;
    let b = run_scenario(&cfg, &trace, "fleet-256-b").metrics;
    assert_eq!(a.frames_completed, b.frames_completed);
    assert_eq!(a.hp_generated, b.hp_generated);
    assert_eq!(a.hp_completed, b.hp_completed);
    assert_eq!(a.lp_generated, b.lp_generated);
    assert_eq!(a.lp_completed, b.lp_completed);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.lp_failed_alloc, b.lp_failed_alloc);
    // Float summaries are deterministic too, to the last bit: finalize
    // folds the per-request set fractions in key-sorted order now, so the
    // accumulated mean no longer depends on HashMap iteration order (the
    // retired KNOWN_ISSUES.md wart). Wall-clock latency summaries are
    // excluded — they measure real time, not simulated state.
    assert!(a.lp_set_fractions.count() > 0, "the scenario must exercise the summary");
    assert_eq!(a.lp_set_fractions.count(), b.lp_set_fractions.count());
    assert_eq!(
        a.lp_set_fractions.mean().to_bits(),
        b.lp_set_fractions.mean().to_bits(),
        "set-fraction mean must be bit-identical across runs"
    );
    assert_eq!(
        a.lp_set_fractions.percentile(50.0).to_bits(),
        b.lp_set_fractions.percentile(50.0).to_bits()
    );
    assert_eq!(
        a.lp_set_fractions.std_dev().to_bits(),
        b.lp_set_fractions.std_dev().to_bits()
    );
    assert_eq!(
        a.lp_per_request_pct().to_bits(),
        b.lp_per_request_pct().to_bits(),
        "Fig 5's derived percentage is bit-identical"
    );
    lp_accounted(&a);
}

#[test]
fn hotspot_fleet_offloads_from_hot_devices() {
    // A skewed fleet is exactly where offloading pays: hot devices generate
    // more DNN sets than they can host and the scheduler spreads the
    // overflow over the idle tail.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.fleet.cycles = 4;
    cfg.frames = 64;
    let profile = FleetProfile {
        pattern: FleetPattern::Hotspot { hot_pct: 20 },
        hp_only_pct: 0,
        lp_weight: 4,
    };
    let trace = Trace::generate_fleet(&profile, 16, 4, 7);
    let m = run_scenario(&cfg, &trace, "hotspot-16").metrics;
    assert!(m.lp_generated > 0);
    assert!(m.lp_offloaded > 0, "hot devices must shed load to the cold tail");
    lp_accounted(&m);
}
