//! Integration: AOT artifacts → PJRT engine → horizontal partitioning.
//!
//! These tests require `make artifacts` to have run (they are skipped with
//! a notice otherwise, so a fresh checkout still passes `cargo test`).
//!
//! The key assertion is the paper's §3.2 invariant end-to-end ACROSS THE
//! LANGUAGE BOUNDARY: the Rust tile/stitch/pool pipeline over the per-tile
//! HLO executables must agree with the monolithic single-executable CNN to
//! float tolerance.

use pats::runtime::{partition, Engine, Tensor};
use pats::util::rng::Rng;

fn engine() -> Option<Engine> {
    let dir = Engine::default_dir();
    if !dir.join("manifest.txt").exists() {
        eprintln!("SKIP: no artifacts at {} (run `make artifacts`)", dir.display());
        return None;
    }
    match Engine::load(&dir) {
        Ok(engine) => Some(engine),
        // Artifacts exist but the engine cannot load — e.g. a default
        // (no-`xla`-feature) build, where Engine is a stub. Skip, same as
        // the missing-artifacts case.
        Err(e) => {
            eprintln!("SKIP: cannot load artifacts ({e}); build with --features xla");
            None
        }
    }
}

fn random_frame(rng: &mut Rng) -> Tensor {
    let data: Vec<f32> = (0..48 * 48 * 3).map(|_| rng.normal(0.0, 1.0) as f32).collect();
    Tensor::new(vec![48, 48, 3], data)
}

#[test]
fn engine_loads_all_artifacts() {
    let Some(engine) = engine() else { return };
    let names: Vec<&str> = engine.names().collect();
    for required in [
        "detector",
        "classifier",
        "cnn_full",
        "head",
        "block0_full",
        "block0_tile2",
        "block0_tile4",
        "pool0",
        "block2_tile4",
        "pool2",
    ] {
        assert!(names.contains(&required), "missing artifact {required}");
    }
    assert_eq!(engine.platform(), "cpu");
}

#[test]
fn detector_semantics() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::seed_from_u64(1);
    let bg = random_frame(&mut rng);
    // Identical frame ⇒ zero score.
    let same = partition::run_detector(&engine, &bg, &bg).unwrap();
    assert_eq!(same, 0.0);
    // Perturbed frame ⇒ positive score.
    let mut frame = bg.clone();
    for v in frame.data.iter_mut().take(500) {
        *v += 1.0;
    }
    let diff = partition::run_detector(&engine, &frame, &bg).unwrap();
    assert!(diff > 0.0);
}

#[test]
fn classifier_runs_and_is_deterministic() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::seed_from_u64(2);
    let frame = random_frame(&mut rng);
    let a = partition::run_classifier(&engine, &frame).unwrap();
    let b = partition::run_classifier(&engine, &frame).unwrap();
    assert_eq!(a, b);
    assert!(a.is_finite());
}

#[test]
fn partitioned_cnn_matches_monolithic() {
    let Some(engine) = engine() else { return };
    let mut rng = Rng::seed_from_u64(3);
    let frame = random_frame(&mut rng);
    let mono = engine.execute("cnn_full", &[&frame]).unwrap();
    assert_eq!(mono.shape, vec![4]);
    for tiles in [1usize, 2, 4] {
        let out = partition::run_cnn(&engine, &frame, tiles).unwrap();
        let diff = out.max_abs_diff(&mono);
        assert!(
            diff < 2e-4,
            "tiles={tiles}: partitioned output diverges by {diff}"
        );
        assert_eq!(out.argmax(), mono.argmax(), "tiles={tiles}: class flipped");
    }
}

#[test]
fn partitioned_cnn_differs_across_inputs() {
    let Some(engine) = engine() else { return };
    // Two iid noise frames give near-identical global-average-pooled
    // features; use structurally different frames instead.
    let zeros = Tensor::zeros(&[48, 48, 3]);
    let ones = Tensor::from_fn(&[48, 48, 3], |_| 1.0);
    let a = partition::run_cnn(&engine, &zeros, 2).unwrap();
    let b = partition::run_cnn(&engine, &ones, 2).unwrap();
    assert!(a.max_abs_diff(&b) > 1e-3, "CNN must not be constant");
}

#[test]
fn execute_validates_shapes() {
    let Some(engine) = engine() else { return };
    let bad = Tensor::zeros(&[4, 4, 3]);
    assert!(engine.execute("cnn_full", &[&bad]).is_err());
    let frame = Tensor::zeros(&[48, 48, 3]);
    assert!(engine.execute("detector", &[&frame]).is_err(), "arity check");
    assert!(engine.execute("nonexistent", &[&frame]).is_err());
}

#[test]
fn full_pipeline_smoke() {
    // Stage 1 → stage 2 → stage 3 over the real artifacts: the quickstart
    // path exercised as a test.
    let Some(engine) = engine() else { return };
    let bg = Tensor::zeros(&[48, 48, 3]);
    let mut frame = bg.clone();
    for h in 10..30 {
        for w in 10..30 {
            for c in 0..3 {
                frame.data[(h * 48 + w) * 3 + c] = 0.9;
            }
        }
    }
    let score = partition::run_detector(&engine, &frame, &bg).unwrap();
    assert!(score > 0.01, "object must be detected");
    let decision = partition::run_classifier(&engine, &frame).unwrap();
    assert!(decision.is_finite());
    let logits = partition::run_cnn(&engine, &frame, 4).unwrap();
    assert_eq!(logits.shape, vec![4]);
    assert!(logits.argmax() < 4);
}
