//! Differential harness for the two simulation engines: the serial
//! reference event loop (`sharding.engine = serial`) and the batched
//! parallel engine (`sharding.engine = parallel`) must be **bit-identical**
//! on every scenario — same final network-state fingerprint, same summary
//! counters, same exported JSON — at every shard count and policy.
//!
//! Parameterised by environment (used by the CI `test-matrix` job):
//!
//! * `PATS_EQ_SHARDS`: comma list of shard counts to test (default `1,4`).
//!   Counts above a scenario's device count are skipped.
//! * `PATS_EQ_ENGINE`: `serial` | `parallel` | `both` (default `both`).
//!   With a single engine the harness still runs every scenario (invariant
//!   smoke + determinism); with `both` it additionally asserts the
//!   engine-vs-engine equivalence.
//! * `PATS_EQ_BROKER`: `on` | `off` (default `off`). With `on`, every
//!   scenario also enables the bandwidth broker and the rebalancer, so the
//!   whole differential suite re-runs with epoch re-leasing and device
//!   migration active. (Broker-on coverage also runs unconditionally in the
//!   dedicated tests below — the knob widens it to every scenario.)
//! * `PATS_EQ_INDEX`: `on` | `off` (unset = leave the default, which is
//!   on). With `off` the whole suite re-runs on the direct O(N) candidate
//!   scans instead of the availability index — the two paths must be
//!   bit-identical (also asserted head-to-head in the dedicated test
//!   below).
//! * `PATS_EQ_PROFILE`: `on` | `off` (unset = leave the default, which is
//!   off). With `on` the whole suite runs with the phase profiler
//!   collecting — profiling must never change a simulated bit (also
//!   asserted head-to-head in the dedicated test below).
//! * `PATS_EQ_TRACE`: `on` | `off` (unset = leave the default, which is
//!   off). With `on` the whole suite runs with the task-lifecycle flight
//!   recorder armed: every engine-vs-engine and repeat-vs-repeat
//!   comparison then also diffs the journal-derived `trace` block of the
//!   deterministic JSON bit-for-bit — the trace-level differential.
//!   (The head-to-head journal equality tests live in `rust/tests/trace.rs`,
//!   which owns the process-wide toggle in default runs.)
//! * `PATS_EQ_EXEC`: `off` | `auto` | a worker count (unset = leave the
//!   default, which is off). When set, every plane in the suite runs with
//!   `[sharding] workers` forced to that value, so the whole differential
//!   re-runs with the persistent work-stealing executor driving the sweep
//!   doors and the nested candidate-plan fan-outs — which must be
//!   bit-identical to the scoped-thread path at every worker count (also
//!   asserted head-to-head in the dedicated test below).

use pats::config::{EngineKind, SystemConfig, WorkerCount};
use pats::coordinator::{ControlSurface, Controller};
use pats::metrics::ScenarioMetrics;
use pats::scheduler::{PatsScheduler, Policy};
use pats::shard::ControlPlane;
use pats::sim::run_with_surface_dynamic;
use pats::task::DeviceId;
use pats::time::SimTime;
use pats::trace::{ChurnEvent, ChurnScript, Distribution, FleetPattern, FleetProfile, Trace};
use pats::workstealer::{Mode, Workstealer};

fn shard_counts() -> Vec<usize> {
    match std::env::var("PATS_EQ_SHARDS") {
        Ok(csv) => csv
            .split(',')
            .map(|s| {
                s.trim()
                    .parse::<usize>()
                    .ok()
                    .filter(|&k| k > 0)
                    .unwrap_or_else(|| panic!("bad PATS_EQ_SHARDS entry {s:?}"))
            })
            .collect(),
        Err(_) => vec![1, 4],
    }
}

fn engines() -> Vec<EngineKind> {
    match std::env::var("PATS_EQ_ENGINE").as_deref() {
        Ok("serial") => vec![EngineKind::Serial],
        Ok("parallel") => vec![EngineKind::Parallel],
        Ok("both") | Err(_) => vec![EngineKind::Serial, EngineKind::Parallel],
        Ok(other) => panic!("PATS_EQ_ENGINE must be serial|parallel|both, got {other:?}"),
    }
}

fn broker_from_env() -> bool {
    match std::env::var("PATS_EQ_BROKER").as_deref() {
        Ok("on") | Ok("1") => true,
        Ok("off") | Ok("0") | Err(_) => false,
        Ok(other) => panic!("PATS_EQ_BROKER must be on|off, got {other:?}"),
    }
}

/// `PATS_EQ_INDEX`: `Some(on?)` when set, `None` to leave the process-wide
/// default untouched (so the dedicated toggle test below owns the switch
/// in default local runs).
fn index_from_env() -> Option<bool> {
    match std::env::var("PATS_EQ_INDEX").as_deref() {
        Ok("on") | Ok("1") => Some(true),
        Ok("off") | Ok("0") => Some(false),
        Err(_) => None,
        Ok(other) => panic!("PATS_EQ_INDEX must be on|off, got {other:?}"),
    }
}

/// `PATS_EQ_PROFILE`: same convention as [`index_from_env`].
fn profile_from_env() -> Option<bool> {
    match std::env::var("PATS_EQ_PROFILE").as_deref() {
        Ok("on") | Ok("1") => Some(true),
        Ok("off") | Ok("0") => Some(false),
        Err(_) => None,
        Ok(other) => panic!("PATS_EQ_PROFILE must be on|off, got {other:?}"),
    }
}

/// `PATS_EQ_TRACE`: same convention as [`index_from_env`]. The environment
/// is constant for the whole process, so applying it per run never tears an
/// engine-vs-engine pair (unlike flipping the toggle from a concurrent
/// test, which `rust/tests/trace.rs` serialises behind a mutex).
fn trace_from_env() -> Option<bool> {
    match std::env::var("PATS_EQ_TRACE").as_deref() {
        Ok("on") | Ok("1") => Some(true),
        Ok("off") | Ok("0") => Some(false),
        Err(_) => None,
        Ok(other) => panic!("PATS_EQ_TRACE must be on|off, got {other:?}"),
    }
}

/// `PATS_EQ_EXEC`: `Some(workers)` when set, `None` to leave the config
/// default (executor off) untouched.
fn exec_from_env() -> Option<WorkerCount> {
    match std::env::var("PATS_EQ_EXEC").as_deref() {
        Ok("off") | Ok("0") => Some(WorkerCount::Off),
        Ok("on") | Ok("auto") => Some(WorkerCount::Auto),
        Ok(n) => Some(WorkerCount::Fixed(
            n.parse::<usize>()
                .ok()
                .filter(|&w| w > 0)
                .unwrap_or_else(|| panic!("PATS_EQ_EXEC must be off|auto|N, got {n:?}")),
        )),
        Err(_) => None,
    }
}

/// The policies the differential runs sweep: the paper's scheduler and the
/// polling central workstealer (a second, structurally different decision
/// path: deferred placement + poll ticks).
#[derive(Debug, Clone, Copy)]
enum Pol {
    Scheduler,
    CentralWorkstealer,
}

struct RunOut {
    metrics: ScenarioMetrics,
    fingerprint: String,
    link_slots: usize,
}

fn run_surface<P: Policy + Send>(
    cfg: &SystemConfig,
    trace: &Trace,
    churn: &ChurnScript,
    engine: EngineKind,
    mut factory: impl FnMut(&SystemConfig) -> P,
) -> RunOut {
    let mut cfg = cfg.clone();
    cfg.sharding.engine = engine;
    if broker_from_env() {
        cfg.sharding.broker.enabled = true;
        cfg.sharding.rebalance.enabled = true;
    }
    if let Some(workers) = exec_from_env() {
        cfg.sharding.workers = workers;
    }
    if let Some(on) = index_from_env() {
        pats::resources::avail::set_enabled(on);
    }
    if let Some(on) = profile_from_env() {
        pats::util::profiler::enable(on);
    }
    if let Some(on) = trace_from_env() {
        pats::obs::enable(on);
    }
    let out = if cfg.sharding.shards == 1 {
        // The production dispatcher drives the raw controller at one shard;
        // the harness does the same so both engines cover it.
        let controller = Controller::new(cfg.clone(), factory(&cfg));
        let (res, c) = run_with_surface_dynamic(&cfg, trace, churn, "eq", controller);
        RunOut {
            metrics: res.metrics,
            fingerprint: ControlSurface::fingerprint(&c),
            link_slots: c.link_slot_count(),
        }
    } else {
        let plane = ControlPlane::new(&cfg, factory);
        let (res, p) = run_with_surface_dynamic(&cfg, trace, churn, "eq", plane);
        p.check_invariants().unwrap();
        RunOut {
            metrics: res.metrics,
            fingerprint: ControlSurface::fingerprint(&p),
            link_slots: p.link_slot_count(),
        }
    };
    if trace_from_env() == Some(true) {
        // Traced runs retain their journal for CLI export; drain it so a
        // whole traced suite does not accumulate every journal in memory.
        let _ = pats::obs::take_recorded();
    }
    out
}

fn run_pol(
    pol: Pol,
    cfg: &SystemConfig,
    trace: &Trace,
    churn: &ChurnScript,
    engine: EngineKind,
) -> RunOut {
    match pol {
        Pol::Scheduler => run_surface(cfg, trace, churn, engine, PatsScheduler::from_config),
        Pol::CentralWorkstealer => run_surface(cfg, trace, churn, engine, |c| {
            Workstealer::new(Mode::Central, c.preemption, c)
        }),
    }
}

/// Every simulated counter must match to the bit between engines
/// (wall-clock latency summaries excluded — they measure real time).
fn assert_metrics_identical(a: &ScenarioMetrics, b: &ScenarioMetrics, ctx: &str) {
    assert_eq!(a.frames_total, b.frames_total, "{ctx}");
    assert_eq!(a.frames_completed, b.frames_completed, "{ctx}");
    assert_eq!(a.frames_failed_hp, b.frames_failed_hp, "{ctx}");
    assert_eq!(a.frames_failed_lp, b.frames_failed_lp, "{ctx}");
    assert_eq!(a.frames_lost_churn, b.frames_lost_churn, "{ctx}");
    assert_eq!(a.hp_generated, b.hp_generated, "{ctx}");
    assert_eq!(a.hp_completed, b.hp_completed, "{ctx}");
    assert_eq!(a.hp_completed_via_preemption, b.hp_completed_via_preemption, "{ctx}");
    assert_eq!(a.hp_failed_alloc, b.hp_failed_alloc, "{ctx}");
    assert_eq!(a.hp_violated, b.hp_violated, "{ctx}");
    assert_eq!(a.hp_orphaned, b.hp_orphaned, "{ctx}");
    assert_eq!(a.hp_rescued, b.hp_rescued, "{ctx}");
    assert_eq!(a.hp_lost_churn, b.hp_lost_churn, "{ctx}");
    assert_eq!(a.lp_generated, b.lp_generated, "{ctx}");
    assert_eq!(a.lp_completed, b.lp_completed, "{ctx}");
    assert_eq!(a.lp_failed_alloc, b.lp_failed_alloc, "{ctx}");
    assert_eq!(a.lp_failed_preempted, b.lp_failed_preempted, "{ctx}");
    assert_eq!(a.lp_violated, b.lp_violated, "{ctx}");
    assert_eq!(a.lp_offloaded, b.lp_offloaded, "{ctx}");
    assert_eq!(a.lp_offloaded_completed, b.lp_offloaded_completed, "{ctx}");
    assert_eq!(a.lp_sets_completed, b.lp_sets_completed, "{ctx}");
    assert_eq!(a.lp_sets_total, b.lp_sets_total, "{ctx}");
    assert_eq!(a.preemptions, b.preemptions, "{ctx}");
    assert_eq!(a.realloc_success, b.realloc_success, "{ctx}");
    assert_eq!(a.realloc_failure, b.realloc_failure, "{ctx}");
    assert_eq!(a.preempted_by_cores, b.preempted_by_cores, "{ctx}");
    assert_eq!(a.core_alloc_local, b.core_alloc_local, "{ctx}");
    assert_eq!(a.core_alloc_offloaded, b.core_alloc_offloaded, "{ctx}");
    // Spill is router-serialised in both engines, so its counters match
    // exactly too.
    assert_eq!(a.lp_requests_spilled, b.lp_requests_spilled, "{ctx}");
    assert_eq!(a.lp_tasks_spilled, b.lp_tasks_spilled, "{ctx}");
    assert_eq!(a.lp_spill_attempts, b.lp_spill_attempts, "{ctx}");
    assert_eq!(a.lp_spill_returned, b.lp_spill_returned, "{ctx}");
    // Float summaries to the bit: identical decisions fold identical
    // values in identical order.
    assert_eq!(a.lp_set_fractions.count(), b.lp_set_fractions.count(), "{ctx}");
    assert_eq!(
        a.lp_set_fractions.mean().to_bits(),
        b.lp_set_fractions.mean().to_bits(),
        "set-fraction mean must be bit-identical ({ctx})"
    );
    assert_eq!(
        a.lp_set_fractions.std_dev().to_bits(),
        b.lp_set_fractions.std_dev().to_bits(),
        "{ctx}"
    );
    assert_eq!(a.accuracy_goodput.to_bits(), b.accuracy_goodput.to_bits(), "{ctx}");
    // The catch-all: every exported counter except the wall-clock block.
    assert_eq!(
        a.deterministic_json().to_string_pretty(),
        b.deterministic_json().to_string_pretty(),
        "deterministic JSON must be byte-identical ({ctx})"
    );
}

/// Run the scenario under every selected engine at every selected shard
/// count × spill fan-out × policy, and assert all engines agree.
fn assert_engines_agree(
    label: &str,
    cfg_base: &SystemConfig,
    trace: &Trace,
    churn: &ChurnScript,
    pols: &[Pol],
) {
    for &k in &shard_counts() {
        if k > cfg_base.devices {
            continue;
        }
        // Fan-out 2 (default) keeps LP admissions router-serialised at
        // K > 1; fan-out 0 lets the parallel engine sweep them too — both
        // paths must agree with the serial engine.
        let fanouts: &[usize] = if k == 1 { &[2] } else { &[2, 0] };
        for &fanout in fanouts {
            for &pol in pols {
                let mut cfg = cfg_base.clone();
                cfg.sharding.shards = k;
                cfg.sharding.spill_fanout = fanout;
                let ctx = format!("{label}, shards={k}, fanout={fanout}, {pol:?}");
                let runs: Vec<(EngineKind, RunOut)> = engines()
                    .into_iter()
                    .map(|e| (e, run_pol(pol, &cfg, trace, churn, e)))
                    .collect();
                let (e0, first) = &runs[0];
                for (e, run) in &runs[1..] {
                    assert_eq!(
                        first.fingerprint, run.fingerprint,
                        "engines {e0} vs {e} left different network states ({ctx})"
                    );
                    assert_metrics_identical(
                        &first.metrics,
                        &run.metrics,
                        &format!("{ctx}, {e0} vs {e}"),
                    );
                }
            }
        }
    }
}

#[test]
fn engines_agree_on_the_seed_scenario() {
    // The paper's 4-device topology, uniform trace — the seed scenario.
    let mut cfg = SystemConfig::default();
    cfg.frames = 80;
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    assert_engines_agree(
        "seed",
        &cfg,
        &trace,
        &ChurnScript::none(),
        &[Pol::Scheduler, Pol::CentralWorkstealer],
    );
}

#[test]
fn engines_agree_under_churn() {
    // Crash + drain + link degradation: barrier events (churn, failure
    // detection, rescue) interleave with the batched admissions.
    let mut cfg = SystemConfig::default();
    cfg.frames = 120;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(45.0), ChurnEvent::Drain(DeviceId(2))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ]);
    assert_engines_agree(
        "churn",
        &cfg,
        &trace,
        &script,
        &[Pol::Scheduler, Pol::CentralWorkstealer],
    );
}

#[test]
fn engines_agree_on_a_256_device_fleet() {
    // Fleet scale: wide same-instant admission waves are where the batched
    // engine actually forms large sweeps. Fan-out 0 so LP admissions ride
    // the parallel sweep path at K > 1.
    let mut cfg = SystemConfig::default();
    cfg.devices = 256;
    cfg.frames = 512;
    cfg.sharding.spill_fanout = 0;
    let profile = FleetProfile {
        pattern: FleetPattern::Diurnal { period_cycles: 16 },
        hp_only_pct: 50,
        lp_weight: 1,
    };
    let trace = Trace::generate_fleet(&profile, 256, 2, cfg.seed);
    for &k in &shard_counts() {
        let mut cfg = cfg.clone();
        cfg.sharding.shards = k;
        let runs: Vec<(EngineKind, RunOut)> = engines()
            .into_iter()
            .map(|e| (e, run_pol(Pol::Scheduler, &cfg, &trace, &ChurnScript::none(), e)))
            .collect();
        let (e0, first) = &runs[0];
        for (e, run) in &runs[1..] {
            assert_eq!(
                first.fingerprint, run.fingerprint,
                "engines {e0} vs {e} left different network states (fleet256, shards={k})"
            );
            assert_metrics_identical(
                &first.metrics,
                &run.metrics,
                &format!("fleet256, shards={k}, {e0} vs {e}"),
            );
        }
    }
}

#[test]
fn availability_index_is_bit_identical_to_the_direct_scan() {
    // The availability index (resources::avail) is a pure pre-filter: the
    // indexed offload and rescue scans must leave the exact network state
    // and counters the direct O(N) scans produce, on the scheduler and at
    // shard counts where each shard's state is fleet-sized. A concurrent
    // test flipping the same process-wide toggle can only ever make the
    // two legs *more* alike, so the assertion is race-free.
    let mut cfg = SystemConfig::default();
    cfg.devices = 32;
    cfg.frames = 192;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::Crash(DeviceId(17))),
    ]);
    for k in [1usize, 4] {
        let mut cfg = cfg.clone();
        cfg.sharding.shards = k;
        pats::resources::avail::set_enabled(false);
        let direct = run_pol(Pol::Scheduler, &cfg, &trace, &script, EngineKind::Serial);
        pats::resources::avail::set_enabled(true);
        let indexed = run_pol(Pol::Scheduler, &cfg, &trace, &script, EngineKind::Serial);
        assert_eq!(
            direct.fingerprint, indexed.fingerprint,
            "index on vs off left different network states (shards={k})"
        );
        assert_metrics_identical(
            &direct.metrics,
            &indexed.metrics,
            &format!("index on vs off, shards={k}"),
        );
        // The scenario actually exercises the scans it compares.
        assert!(indexed.metrics.lp_generated > 0 && indexed.metrics.failures_detected > 0);
    }
    // Restore the suite-wide setting.
    pats::resources::avail::set_enabled(index_from_env().unwrap_or(true));
}

#[test]
fn profiler_on_output_is_byte_identical_to_profiler_off() {
    // The profiler reads wall clocks and thread-local counters only — it
    // must never change a simulated bit. Deterministic JSON and the state
    // fingerprint are compared byte-for-byte across the toggle.
    let mut cfg = SystemConfig::default();
    cfg.frames = 120;
    let trace = Trace::generate(Distribution::Weighted(2), cfg.devices, cfg.frames, cfg.seed);
    pats::util::profiler::enable(false);
    let off = run_pol(Pol::Scheduler, &cfg, &trace, &ChurnScript::none(), EngineKind::Serial);
    pats::util::profiler::enable(true);
    let on = run_pol(Pol::Scheduler, &cfg, &trace, &ChurnScript::none(), EngineKind::Serial);
    assert!(
        pats::util::profiler::report().is_some(),
        "the profiled run must have collected phase data"
    );
    pats::util::profiler::enable(profile_from_env().unwrap_or(false));
    assert_eq!(
        off.fingerprint, on.fingerprint,
        "profiling changed the final network state"
    );
    assert_metrics_identical(&off.metrics, &on.metrics, "profiler on vs off");
    assert_eq!(
        off.metrics.deterministic_json().to_string_pretty(),
        on.metrics.deterministic_json().to_string_pretty(),
        "profiler on vs off must serialise byte-identical JSON"
    );
}

#[test]
fn repeated_parallel_runs_serialise_byte_identical_metrics() {
    // Determinism stress: 16 repeats of the same churning scenario must
    // serialise byte-identical deterministic JSON — no run-to-run drift
    // from thread scheduling in the shard sweeps.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 96;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(45.0), ChurnEvent::Crash(DeviceId(9))),
        (SimTime::from_secs_f64(50.0), ChurnEvent::Drain(DeviceId(2))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ]);
    for engine in engines() {
        for k in [4usize, 8] {
            let mut cfg = cfg.clone();
            cfg.sharding.shards = k;
            let reference = run_pol(Pol::Scheduler, &cfg, &trace, &script, engine);
            let ref_json = reference.metrics.deterministic_json().to_string_pretty();
            assert!(!ref_json.is_empty());
            for rep in 1..16 {
                let run = run_pol(Pol::Scheduler, &cfg, &trace, &script, engine);
                assert_eq!(
                    reference.fingerprint, run.fingerprint,
                    "repeat {rep} diverged ({engine}, shards={k})"
                );
                assert_eq!(
                    ref_json,
                    run.metrics.deterministic_json().to_string_pretty(),
                    "repeat {rep} produced different JSON ({engine}, shards={k})"
                );
            }
        }
    }
}

#[test]
fn executor_on_is_bit_identical_to_scoped_threads() {
    // The work-stealing executor changes *where* sweep jobs and candidate
    // plans run, never what they compute: with `[sharding] workers` armed,
    // every engine and shard count must leave the exact network state and
    // byte-identical deterministic JSON the scoped-thread path produces.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 96;
    cfg.sharding.spill_fanout = 0; // LP admissions ride the sweep path
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(50.0), ChurnEvent::Drain(DeviceId(2))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ]);
    for engine in engines() {
        for k in [2usize, 4] {
            let mut off_cfg = cfg.clone();
            off_cfg.sharding.shards = k;
            off_cfg.sharding.workers = WorkerCount::Off;
            let off = run_pol(Pol::Scheduler, &off_cfg, &trace, &script, engine);
            // The scenario exercises the paths the executor parallelises.
            assert!(off.metrics.preemptions > 0, "scenario never preempted");
            assert!(off.metrics.failures_detected > 0, "scenario never rescued");
            for workers in [1usize, 3, 8] {
                let mut on_cfg = off_cfg.clone();
                on_cfg.sharding.workers = WorkerCount::Fixed(workers);
                let on = run_pol(Pol::Scheduler, &on_cfg, &trace, &script, engine);
                assert_eq!(
                    off.fingerprint, on.fingerprint,
                    "executor workers={workers} left a different network state \
                     ({engine}, shards={k})"
                );
                assert_metrics_identical(
                    &off.metrics,
                    &on.metrics,
                    &format!("executor off vs workers={workers}, {engine}, shards={k}"),
                );
            }
        }
    }
}

#[test]
fn repeated_executor_runs_serialise_byte_identical_metrics() {
    // Determinism stress for the pool: 16 repeats at every worker count on
    // a churning hotspot scenario must serialise byte-identical
    // deterministic JSON — no drift from steal order, park/unpark timing,
    // or injector chunking — and all worker counts must agree with the
    // workers-off reference.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 96;
    cfg.sharding.shards = 4;
    cfg.sharding.spill_fanout = 0;
    let profile = FleetProfile {
        pattern: FleetPattern::Hotspot { hot_pct: 25 },
        hp_only_pct: 0,
        lp_weight: 4,
    };
    let trace = Trace::generate_fleet(&profile, cfg.devices, 6, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(35.0), ChurnEvent::Crash(DeviceId(2))),
        (SimTime::from_secs_f64(50.0), ChurnEvent::Drain(DeviceId(11))),
        (SimTime::from_secs_f64(70.0), ChurnEvent::DegradeLink { factor: 0.8 }),
        (SimTime::from_secs_f64(95.0), ChurnEvent::RestoreLink),
    ]);
    let reference =
        run_pol(Pol::Scheduler, &cfg, &trace, &script, EngineKind::Parallel);
    let ref_json = reference.metrics.deterministic_json().to_string_pretty();
    for workers in [1usize, 2, 4, 8] {
        let mut cfg = cfg.clone();
        cfg.sharding.workers = WorkerCount::Fixed(workers);
        for rep in 0..16 {
            let run = run_pol(Pol::Scheduler, &cfg, &trace, &script, EngineKind::Parallel);
            assert_eq!(
                reference.fingerprint, run.fingerprint,
                "workers={workers} repeat {rep} diverged from the scoped reference"
            );
            assert_eq!(
                ref_json,
                run.metrics.deterministic_json().to_string_pretty(),
                "workers={workers} repeat {rep} produced different JSON"
            );
        }
    }
}

#[test]
fn engines_agree_with_broker_and_rebalance_on() {
    // The broker epoch rides the prune barrier, which both engines hit at
    // identical virtual instants — so re-leasing and migration must keep
    // the engines bit-identical. Runs broker-on regardless of
    // PATS_EQ_BROKER so local default runs cover it too.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 96; // 6 cycles ≈ 113 virtual seconds: crosses prune barriers
    cfg.sharding.broker.enabled = true;
    cfg.sharding.rebalance.enabled = true;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ]);
    assert_engines_agree(
        "broker-on",
        &cfg,
        &trace,
        &script,
        &[Pol::Scheduler, Pol::CentralWorkstealer],
    );
    // The differential above is not vacuous: at K > 1 the broker actually
    // runs epochs on this scenario.
    let mut cfg4 = cfg.clone();
    cfg4.sharding.shards = 4;
    let run = run_pol(Pol::Scheduler, &cfg4, &trace, &script, EngineKind::Serial);
    assert!(run.metrics.broker_epochs > 0, "broker never acted at K=4");
}

#[test]
fn broker_on_at_one_shard_is_bit_identical_to_the_unsharded_controller() {
    // K=1 gives the broker nothing to re-lease and the rebalancer nowhere
    // to move devices: the whole subsystem must go dormant, leaving the
    // 1-shard plane bit-identical to the raw pre-shard controller.
    let mut cfg = SystemConfig::default();
    cfg.devices = 8;
    cfg.frames = 96;
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![(
        SimTime::from_secs_f64(40.0),
        ChurnEvent::Crash(DeviceId(3)),
    )]);
    for engine in engines() {
        let mut raw_cfg = cfg.clone();
        raw_cfg.sharding.engine = engine;
        let controller = Controller::new(raw_cfg.clone(), PatsScheduler::from_config(&raw_cfg));
        let (raw_res, c) = run_with_surface_dynamic(&raw_cfg, &trace, &script, "raw", controller);

        let mut plane_cfg = raw_cfg.clone();
        plane_cfg.sharding.broker.enabled = true;
        plane_cfg.sharding.rebalance.enabled = true;
        let plane: ControlPlane<PatsScheduler> =
            ControlPlane::new(&plane_cfg, PatsScheduler::from_config);
        let (plane_res, p) = run_with_surface_dynamic(&plane_cfg, &trace, &script, "k1", plane);
        p.check_invariants().unwrap();

        assert_eq!(
            ControlSurface::fingerprint(&c),
            ControlSurface::fingerprint(&p),
            "broker-on 1-shard plane drifted from the raw controller ({engine})"
        );
        assert_metrics_identical(
            &raw_res.metrics,
            &plane_res.metrics,
            &format!("broker-on K=1 vs raw, {engine}"),
        );
        assert_eq!(plane_res.metrics.broker_epochs, 0, "K=1 broker must stay dormant");
        assert_eq!(plane_res.metrics.devices_migrated, 0);
    }
}

#[test]
fn repeated_broker_runs_serialise_byte_identical_metrics() {
    // Determinism stress for the broker + rebalancer: 16 repeats of a
    // churning hotspot scenario with re-leasing and migration active must
    // serialise byte-identical deterministic JSON on both engines.
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.frames = 192; // 12 cycles ≈ 226 virtual seconds: several broker epochs
    cfg.sharding.broker.enabled = true;
    cfg.sharding.rebalance.enabled = true;
    let profile = FleetProfile {
        pattern: FleetPattern::Hotspot { hot_pct: 25 },
        hp_only_pct: 0,
        lp_weight: 4,
    };
    let trace = Trace::generate_fleet(&profile, cfg.devices, 12, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(70.0), ChurnEvent::Crash(DeviceId(2))),
        (SimTime::from_secs_f64(100.0), ChurnEvent::Drain(DeviceId(11))),
        (SimTime::from_secs_f64(140.0), ChurnEvent::DegradeLink { factor: 0.8 }),
        (SimTime::from_secs_f64(180.0), ChurnEvent::RestoreLink),
    ]);
    for engine in engines() {
        for k in [4usize, 8] {
            let mut cfg = cfg.clone();
            cfg.sharding.shards = k;
            let reference = run_pol(Pol::Scheduler, &cfg, &trace, &script, engine);
            assert!(
                reference.metrics.broker_epochs > 0,
                "broker never acted ({engine}, shards={k})"
            );
            let ref_json = reference.metrics.deterministic_json().to_string_pretty();
            for rep in 1..16 {
                let run = run_pol(Pol::Scheduler, &cfg, &trace, &script, engine);
                assert_eq!(
                    reference.fingerprint, run.fingerprint,
                    "broker repeat {rep} diverged ({engine}, shards={k})"
                );
                assert_eq!(
                    ref_json,
                    run.metrics.deterministic_json().to_string_pretty(),
                    "broker repeat {rep} produced different JSON ({engine}, shards={k})"
                );
            }
        }
    }
}

#[test]
fn barrier_epoch_pruning_keeps_the_link_calendar_bounded() {
    // A long trace accumulates thousands of finished link reservations;
    // both engines must compact at the 60 s prune epochs so the calendar
    // stays O(active horizon), never O(total history). The batched engine
    // prunes at batch barriers only — this is the regression test that the
    // hoisted prune actually fires there.
    let mut cfg = SystemConfig::default();
    cfg.frames = 600; // 150 cycles ≈ 47 virtual minutes on 4 devices
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    for engine in engines() {
        for &k in &shard_counts() {
            if k > cfg.devices {
                continue;
            }
            let mut cfg = cfg.clone();
            cfg.sharding.shards = k;
            let run = run_pol(Pol::Scheduler, &cfg, &trace, &ChurnScript::none(), engine);
            assert!(
                run.metrics.hp_generated >= 500,
                "the long trace must actually generate work"
            );
            // Unpruned, the calendar would hold several slots per frame
            // (well over 2000 here); pruned it only covers the last prune
            // epoch plus the live horizon.
            assert!(
                run.link_slots <= 400,
                "link calendar grew to {} slots under {engine}, shards={k} — \
                 prune_before is not firing",
                run.link_slots
            );
        }
    }
}
