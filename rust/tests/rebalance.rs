//! Dynamic re-sharding integration: a hotspot fleet with the broker and
//! rebalancer enabled conserves every task and frame end-to-end, and a
//! scripted migration hands a boundary device off cleanly — including a
//! crash that lands *after* the device moved, which must be reclaimed by
//! the new home shard exactly once.

use pats::config::SystemConfig;
use pats::coordinator::ControlSurface;
use pats::scheduler::PatsScheduler;
use pats::shard::ControlPlane;
use pats::sim::run_with_surface_dynamic;
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;
use pats::trace::{ChurnEvent, ChurnScript, FleetPattern, FleetProfile, Trace};

/// A fleet where all the heat sits in shard 0: the hot block is the
/// low-numbered quarter of the devices, which contiguous homing maps onto
/// the first shard — sustained demand skew by construction.
fn hotspot_cfg() -> (SystemConfig, Trace) {
    let mut cfg = SystemConfig::default();
    cfg.devices = 16;
    cfg.sharding.shards = 4;
    cfg.sharding.broker.enabled = true;
    cfg.sharding.rebalance.enabled = true;
    let cycles = 24; // ~450 s of virtual time: crosses many 60 s prune barriers
    cfg.frames = (cfg.devices * cycles) as u64;
    let profile = FleetProfile {
        pattern: FleetPattern::Hotspot { hot_pct: 25 },
        hp_only_pct: 0,
        lp_weight: 4,
    };
    let trace = Trace::generate_fleet(&profile, cfg.devices, cycles, cfg.seed);
    (cfg, trace)
}

#[test]
fn hotspot_run_with_broker_and_rebalance_conserves_every_task_and_frame() {
    let (cfg, trace) = hotspot_cfg();
    // Two mid-run crashes (one hot, one cold device) so reclamation and
    // re-leasing overlap with live migrations.
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(130.0), ChurnEvent::Crash(DeviceId(2))),
        (SimTime::from_secs_f64(200.0), ChurnEvent::Crash(DeviceId(13))),
    ]);
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let (result, plane) = run_with_surface_dynamic(&cfg, &trace, &script, "hotspot", plane);
    let m = &result.metrics;
    plane.check_invariants().unwrap();
    assert!(m.broker_epochs > 0, "a 450 s run must cross broker epochs");
    assert!(m.lp_generated > 0);
    // Conservation: re-leasing and migration move capacity and ownership
    // around, but every generated task still lands in exactly one terminal
    // account and every frame in exactly one bucket.
    assert_eq!(
        m.hp_completed + m.hp_failed_alloc + m.hp_violated + m.hp_lost_churn,
        m.hp_generated,
        "HP conservation under broker + rebalance"
    );
    assert_eq!(
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated
            + m.lp_lost_churn,
        m.lp_generated,
        "LP conservation under broker + rebalance"
    );
    assert_eq!(
        m.frames_completed + m.frames_failed_hp + m.frames_failed_lp + m.frames_lost_churn,
        m.frames_total,
        "frame accounting under broker + rebalance"
    );
    // The per-shard registries stay disjoint and sum to the generated
    // totals even after devices changed hands.
    let mut total_tasks = 0u64;
    let mut seen = std::collections::HashSet::new();
    for s in 0..plane.num_shards() {
        for rec in plane.shard(s).state.tasks() {
            assert!(seen.insert(rec.spec.id), "{:?} in two shards", rec.spec.id);
            total_tasks += 1;
        }
    }
    assert_eq!(total_tasks, m.hp_generated + m.lp_generated);
}

#[test]
fn sustained_skew_migrates_and_a_post_migration_crash_reclaims_exactly_once() {
    // Scripted, fully deterministic version of the migration story:
    // 2 shards x 2 devices, all demand on device 0 (shard 0), the
    // boundary device 1 idle throughout.
    let mut cfg = SystemConfig::default();
    cfg.devices = 4;
    cfg.sharding.shards = 2;
    cfg.sharding.broker.enabled = true;
    cfg.sharding.rebalance.enabled = true; // defaults: threshold 1.5, 3 epochs, 1 move
    let mut plane: ControlPlane<PatsScheduler> =
        ControlPlane::new(&cfg, PatsScheduler::from_config);
    assert_eq!(plane.home_shard(DeviceId(1)), 0);
    let t = SimTime::from_secs_f64;

    // Three epochs of one-sided demand: HP traffic on device 0 only, so
    // shard 0 is hot every epoch while device 1 stays quiescent.
    for e in 1..=3u64 {
        let now = t(70.0 * e as f64 - 10.0);
        let _ = ControlSurface::handle_hp_request(&mut plane, FrameId(e), DeviceId(0), now);
        ControlSurface::epoch(&mut plane, t(70.0 * e as f64));
    }
    assert_eq!(
        plane.broker().devices_migrated,
        1,
        "three consecutive skewed epochs must fire exactly one migration"
    );
    assert_eq!(
        plane.home_shard(DeviceId(1)),
        1,
        "the quiescent boundary device re-homes to the cold shard"
    );
    plane.check_invariants().unwrap();

    // The migrated device serves traffic from its new shard, and only the
    // new shard's registry holds the task.
    let (task, _, out) =
        ControlSurface::handle_hp_request(&mut plane, FrameId(100), DeviceId(1), t(215.0));
    assert!(out.window.is_some(), "migrated device must be schedulable in its new shard");
    assert!(plane.shard(1).state.tasks().any(|r| r.spec.id == task));
    assert!(plane.shard(0).state.tasks().all(|r| r.spec.id != task));

    // Crash the migrated device: the failure must route to its *current*
    // home shard, which reclaims the orphan exactly once; the former home
    // shard's state is untouched to the bit.
    let before_old = plane.shard(0).state.fingerprint();
    let rescue = ControlSurface::handle_device_failure(&mut plane, DeviceId(1), t(216.0));
    assert_eq!(rescue.total(), 1, "exactly one orphan, accounted exactly once");
    assert_eq!(
        plane.shard(0).state.fingerprint(),
        before_old,
        "the former home shard must not double-reclaim a migrated device's crash"
    );
    plane.check_invariants().unwrap();
    // Post-crash, every task is still registered in exactly one shard.
    let mut seen = std::collections::HashSet::new();
    for s in 0..plane.num_shards() {
        for rec in plane.shard(s).state.tasks() {
            assert!(seen.insert(rec.spec.id), "{:?} in two shards", rec.spec.id);
        }
    }
}

#[test]
fn rebalance_alone_never_changes_the_static_lease_split() {
    // `[sharding.rebalance]` without the broker: devices may migrate but
    // the medium keeps the even 1/K split — the two subsystems are
    // independently switchable.
    let mut cfg = SystemConfig::default();
    cfg.devices = 4;
    cfg.sharding.shards = 2;
    cfg.sharding.rebalance.enabled = true;
    let mut plane: ControlPlane<PatsScheduler> =
        ControlPlane::new(&cfg, PatsScheduler::from_config);
    let t = SimTime::from_secs_f64;
    for e in 1..=3u64 {
        let now = t(70.0 * e as f64 - 10.0);
        let _ = ControlSurface::handle_hp_request(&mut plane, FrameId(e), DeviceId(0), now);
        ControlSurface::epoch(&mut plane, t(70.0 * e as f64));
    }
    assert_eq!(plane.broker().devices_migrated, 1);
    assert_eq!(plane.broker().epochs, 0, "no broker: no lease epochs counted");
    for &lease in plane.leases() {
        assert_eq!(lease.to_bits(), 0.5f64.to_bits(), "lease split must stay static");
    }
    plane.check_invariants().unwrap();
}
