//! Randomized property tests on the reservation calendars — the data
//! structures every scheduling decision rests on.

use pats::resources::{CoreTimeline, SlotKind, Timeline};
use pats::task::{TaskId, Window};
use pats::time::{SimDuration, SimTime};
use pats::util::prop::{run, Gen};

fn random_kind(g: &mut Gen) -> SlotKind {
    *g.pick(&[
        SlotKind::HpAllocMsg,
        SlotKind::LpAllocMsg,
        SlotKind::InputTransfer,
        SlotKind::StateUpdate,
        SlotKind::PreemptMsg,
        SlotKind::PollMsg,
    ])
}

#[test]
fn timeline_random_ops_preserve_invariants() {
    run("timeline ops", 300, |g| {
        let mut tl = Timeline::new();
        let mut owners: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 60) {
            match g.usize(0, 3) {
                // reserve_earliest never fails and never overlaps.
                0 | 1 => {
                    let owner = TaskId(step as u64);
                    let not_before = SimTime::from_micros(g.u64(0, 100_000));
                    let dur = SimDuration::from_micros(g.u64(1, 10_000));
                    let kind = random_kind(g);
                    let w = tl.reserve_earliest(not_before, dur, kind, owner);
                    assert!(w.start >= not_before);
                    assert_eq!(w.duration(), dur);
                    owners.push(owner);
                }
                // explicit reserve: on success no overlap; on failure state
                // unchanged (len constant).
                2 => {
                    let owner = TaskId(1_000_000 + step as u64);
                    let before = tl.len();
                    let start = SimTime::from_micros(g.u64(0, 100_000));
                    let dur = SimDuration::from_micros(g.u64(1, 10_000));
                    if tl.reserve(start, dur, SlotKind::PollMsg, owner).is_ok() {
                        owners.push(owner);
                    } else {
                        assert_eq!(tl.len(), before);
                    }
                }
                // remove a random owner: all its slots vanish.
                _ => {
                    if !owners.is_empty() {
                        let idx = g.usize(0, owners.len() - 1);
                        let owner = owners.swap_remove(idx);
                        tl.remove_owner(owner);
                        assert!(tl.slots().iter().all(|s| s.owner != owner));
                    }
                }
            }
            tl.check_invariants().unwrap();
        }
    });
}

#[test]
fn timeline_earliest_fit_is_earliest_and_feasible() {
    run("earliest fit minimality", 200, |g| {
        let mut tl = Timeline::new();
        for i in 0..g.usize(0, 30) {
            let start = SimTime::from_micros(g.u64(0, 50_000));
            let dur = SimDuration::from_micros(g.u64(1, 3_000));
            let _ = tl.reserve(start, dur, SlotKind::PollMsg, TaskId(i as u64));
        }
        let not_before = SimTime::from_micros(g.u64(0, 60_000));
        let dur = SimDuration::from_micros(g.u64(1, 5_000));
        let fit = tl.earliest_fit(not_before, dur);
        // Feasible: reserving there must succeed.
        let mut probe = tl.clone();
        probe.reserve(fit, dur, SlotKind::PollMsg, TaskId(u64::MAX)).unwrap();
        // Minimal at µs granularity near the found point: one µs earlier
        // (if still >= not_before) must collide.
        if fit > not_before {
            let earlier = SimTime::from_micros(fit.as_micros() - 1);
            let mut probe = tl.clone();
            assert!(
                probe.reserve(earlier, dur, SlotKind::PollMsg, TaskId(u64::MAX)).is_err(),
                "fit {fit} was not minimal"
            );
        }
    });
}

#[test]
fn core_timeline_never_exceeds_capacity() {
    run("core capacity", 300, |g| {
        let capacity = g.u64(1, 8) as u32;
        let mut ct = CoreTimeline::new(capacity);
        let mut live: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 50) {
            if g.bool(0.7) {
                let start = SimTime::from_micros(g.u64(0, 80_000));
                let dur = SimDuration::from_micros(g.u64(1, 30_000));
                let cores = g.u64(1, capacity as u64) as u32;
                let w = Window::from_duration(start, dur);
                let id = TaskId(step as u64);
                let fits = ct.fits(&w, cores);
                let res = ct.reserve(w, cores, id, w.end, true);
                assert_eq!(res.is_ok(), fits, "reserve must agree with fits()");
                if res.is_ok() {
                    live.push(id);
                }
            } else if !live.is_empty() {
                let idx = g.usize(0, live.len() - 1);
                assert_eq!(ct.remove_task(live.swap_remove(idx)), 1);
            }
            ct.check_invariants().unwrap();
            // Exhaustive capacity check at every reservation boundary.
            for s in ct.slots() {
                assert!(ct.usage_at(s.window.start) <= capacity);
            }
        }
    });
}

#[test]
fn core_timeline_completion_points_are_exact() {
    run("completion points", 200, |g| {
        let mut ct = CoreTimeline::new(16);
        let mut ends = Vec::new();
        for i in 0..g.usize(0, 25) {
            let start = SimTime::from_micros(g.u64(0, 50_000));
            let dur = SimDuration::from_micros(g.u64(1, 20_000));
            let w = Window::from_duration(start, dur);
            if ct.reserve(w, 1, TaskId(i as u64), w.end, true).is_ok() {
                ends.push(w.end);
            }
        }
        let after = SimTime::from_micros(g.u64(0, 40_000));
        let until = SimTime::from_micros(g.u64(40_001, 120_000));
        let got = ct.completion_points(after, until);
        let mut want: Vec<SimTime> =
            ends.iter().copied().filter(|&e| e > after && e <= until).collect();
        want.sort_unstable();
        want.dedup();
        assert_eq!(got, want);
        // Sorted ascending.
        assert!(got.windows(2).all(|p| p[0] < p[1]));
    });
}

#[test]
fn preemption_candidates_ordering_property() {
    run("victim ordering", 200, |g| {
        let mut ct = CoreTimeline::new(64);
        for i in 0..g.usize(1, 20) {
            let w = Window::new(SimTime::ZERO, SimTime::from_micros(g.u64(1, 50_000)));
            let deadline = SimTime::from_micros(g.u64(0, 100_000));
            let preemptible = g.bool(0.8);
            ct.reserve(w, 1, TaskId(i as u64), deadline, preemptible).unwrap();
        }
        let probe = Window::new(SimTime::ZERO, SimTime::from_micros(1));
        let cands = ct.preemption_candidates(&probe);
        // All preemptible, deadlines non-increasing.
        assert!(cands.iter().all(|s| s.preemptible));
        assert!(cands.windows(2).all(|p| p[0].deadline >= p[1].deadline));
    });
}
