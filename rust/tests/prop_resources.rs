//! Randomized property tests on the reservation calendars — the data
//! structures every scheduling decision rests on.

use std::rc::Rc;

use pats::config::SystemConfig;
use pats::resources::avail;
use pats::resources::{CoreTimeline, SlotKind, Timeline};
use pats::scheduler::plan::PlacementPlan;
use pats::state::{DeviceHealth, NetworkState};
use pats::task::{Allocation, DeviceId, FailReason, FrameId, Priority, TaskId, TaskSpec, Window};
use pats::time::{SimDuration, SimTime};
use pats::util::prop::{run, Gen};

fn random_kind(g: &mut Gen) -> SlotKind {
    *g.pick(&[
        SlotKind::HpAllocMsg,
        SlotKind::LpAllocMsg,
        SlotKind::InputTransfer,
        SlotKind::StateUpdate,
        SlotKind::PreemptMsg,
        SlotKind::PollMsg,
    ])
}

#[test]
fn timeline_random_ops_preserve_invariants() {
    run("timeline ops", 300, |g| {
        let mut tl = Timeline::new();
        let mut owners: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 60) {
            match g.usize(0, 3) {
                // reserve_earliest never fails and never overlaps.
                0 | 1 => {
                    let owner = TaskId(step as u64);
                    let not_before = SimTime::from_micros(g.u64(0, 100_000));
                    let dur = SimDuration::from_micros(g.u64(1, 10_000));
                    let kind = random_kind(g);
                    let w = tl.reserve_earliest(not_before, dur, kind, owner);
                    assert!(w.start >= not_before);
                    assert_eq!(w.duration(), dur);
                    owners.push(owner);
                }
                // explicit reserve: on success no overlap; on failure state
                // unchanged (len constant).
                2 => {
                    let owner = TaskId(1_000_000 + step as u64);
                    let before = tl.len();
                    let start = SimTime::from_micros(g.u64(0, 100_000));
                    let dur = SimDuration::from_micros(g.u64(1, 10_000));
                    if tl.reserve(start, dur, SlotKind::PollMsg, owner).is_ok() {
                        owners.push(owner);
                    } else {
                        assert_eq!(tl.len(), before);
                    }
                }
                // remove a random owner: all its slots vanish.
                _ => {
                    if !owners.is_empty() {
                        let idx = g.usize(0, owners.len() - 1);
                        let owner = owners.swap_remove(idx);
                        tl.remove_owner(owner);
                        assert!(tl.slots().iter().all(|s| s.owner != owner));
                    }
                }
            }
            tl.check_invariants().unwrap();
        }
    });
}

#[test]
fn timeline_earliest_fit_is_earliest_and_feasible() {
    run("earliest fit minimality", 200, |g| {
        let mut tl = Timeline::new();
        for i in 0..g.usize(0, 30) {
            let start = SimTime::from_micros(g.u64(0, 50_000));
            let dur = SimDuration::from_micros(g.u64(1, 3_000));
            let _ = tl.reserve(start, dur, SlotKind::PollMsg, TaskId(i as u64));
        }
        let not_before = SimTime::from_micros(g.u64(0, 60_000));
        let dur = SimDuration::from_micros(g.u64(1, 5_000));
        let fit = tl.earliest_fit(not_before, dur);
        // Feasible: reserving there must succeed.
        let mut probe = tl.clone();
        probe.reserve(fit, dur, SlotKind::PollMsg, TaskId(u64::MAX)).unwrap();
        // Minimal at µs granularity near the found point: one µs earlier
        // (if still >= not_before) must collide.
        if fit > not_before {
            let earlier = SimTime::from_micros(fit.as_micros() - 1);
            let mut probe = tl.clone();
            assert!(
                probe.reserve(earlier, dur, SlotKind::PollMsg, TaskId(u64::MAX)).is_err(),
                "fit {fit} was not minimal"
            );
        }
    });
}

#[test]
fn core_timeline_never_exceeds_capacity() {
    run("core capacity", 300, |g| {
        let capacity = g.u64(1, 8) as u32;
        let mut ct = CoreTimeline::new(capacity);
        let mut live: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 50) {
            if g.bool(0.7) {
                let start = SimTime::from_micros(g.u64(0, 80_000));
                let dur = SimDuration::from_micros(g.u64(1, 30_000));
                let cores = g.u64(1, capacity as u64) as u32;
                let w = Window::from_duration(start, dur);
                let id = TaskId(step as u64);
                let fits = ct.fits(&w, cores);
                let res = ct.reserve(w, cores, id, w.end, true);
                assert_eq!(res.is_ok(), fits, "reserve must agree with fits()");
                if res.is_ok() {
                    live.push(id);
                }
            } else if !live.is_empty() {
                let idx = g.usize(0, live.len() - 1);
                assert_eq!(ct.remove_task(live.swap_remove(idx)), 1);
            }
            ct.check_invariants().unwrap();
            // Exhaustive capacity check at every reservation boundary.
            for s in ct.slots() {
                assert!(ct.usage_at(s.window.start) <= capacity);
            }
        }
    });
}

#[test]
fn core_timeline_completion_points_are_exact() {
    run("completion points", 200, |g| {
        let mut ct = CoreTimeline::new(16);
        let mut ends = Vec::new();
        for i in 0..g.usize(0, 25) {
            let start = SimTime::from_micros(g.u64(0, 50_000));
            let dur = SimDuration::from_micros(g.u64(1, 20_000));
            let w = Window::from_duration(start, dur);
            if ct.reserve(w, 1, TaskId(i as u64), w.end, true).is_ok() {
                ends.push(w.end);
            }
        }
        let after = SimTime::from_micros(g.u64(0, 40_000));
        let until = SimTime::from_micros(g.u64(40_001, 120_000));
        let got = ct.completion_points(after, until);
        let mut want: Vec<SimTime> =
            ends.iter().copied().filter(|&e| e > after && e <= until).collect();
        want.sort_unstable();
        want.dedup();
        assert_eq!(got, want);
        // Sorted ascending.
        assert!(got.windows(2).all(|p| p[0] < p[1]));
    });
}

#[test]
fn preemption_candidates_ordering_property() {
    run("victim ordering", 200, |g| {
        let mut ct = CoreTimeline::new(64);
        for i in 0..g.usize(1, 20) {
            let w = Window::new(SimTime::ZERO, SimTime::from_micros(g.u64(1, 50_000)));
            let deadline = SimTime::from_micros(g.u64(0, 100_000));
            let preemptible = g.bool(0.8);
            ct.reserve(w, 1, TaskId(i as u64), deadline, preemptible).unwrap();
        }
        let probe = Window::new(SimTime::ZERO, SimTime::from_micros(1));
        let cands = ct.preemption_candidates(&probe);
        // All preemptible, deadlines non-increasing.
        assert!(cands.iter().all(|s| s.preemptible));
        assert!(cands.windows(2).all(|p| p[0].deadline >= p[1].deadline));
    });
}

// ---------------------------------------------------------------------
// Pooled scratch timelines (scheduler::plan + resources::pool)
// ---------------------------------------------------------------------

/// Build a network state with a handful of committed base link slots.
/// Returns the state plus the `(owner, start)` handles of those slots so
/// tests can also exercise unstaging *base* reservations through a plan.
fn state_with_base_slots(g: &mut Gen) -> (NetworkState, Vec<(TaskId, SimTime)>) {
    let cfg = SystemConfig::default();
    let mut st = NetworkState::new(&cfg);
    let mut base = Vec::new();
    for i in 0..g.usize(1, 6) {
        let owner = TaskId(900_000 + i as u64);
        let not_before = SimTime::from_micros(g.u64(0, 50_000));
        let dur = SimDuration::from_micros(g.u64(1, 5_000));
        let w = st.charge_link_message(not_before, dur, random_kind(g), owner);
        base.push((owner, w.start));
    }
    (st, base)
}

/// A plan whose scratch timeline came out of the reuse pool must be
/// observationally identical to one built on a fresh `link().clone()`:
/// same success/failure per staged op, same windows, same final slot set.
/// The pool is warmed by opening, staging into, and dropping a first plan
/// so the second plan's fork is a pool hit rather than a cold clone.
#[test]
fn pooled_scratch_timeline_matches_fresh_clone() {
    run("pooled scratch ≡ fresh clone", 150, |g| {
        let (st, base) = state_with_base_slots(g);
        let pristine = st.link().clone();

        // Warm the pool: stage a few throwaway ops, then drop the plan.
        {
            let mut warm = PlacementPlan::new(&st);
            for i in 0..g.usize(1, 10) {
                let _ = warm.stage_link_earliest(
                    &st,
                    SimTime::from_micros(g.u64(0, 40_000)),
                    SimDuration::from_micros(g.u64(1, 4_000)),
                    random_kind(g),
                    TaskId(300_000 + i as u64),
                );
            }
        }
        assert!(
            st.link().same_reservations(&pristine),
            "dropping the warm plan must roll the calendar back"
        );

        // Second plan: its first fork should reuse the pooled timeline.
        // Mirror every op onto an explicit fresh clone and compare.
        let mut reference = st.link().clone();
        let mut plan = PlacementPlan::new(&st);
        let mut staged: Vec<(TaskId, SimTime)> = base.clone();
        let first = TaskId(400_000);
        let dur = SimDuration::from_micros(10);
        let got = plan.stage_link_earliest(&st, SimTime::ZERO, dur, SlotKind::PollMsg, first);
        let want = reference.reserve_earliest(SimTime::ZERO, dur, SlotKind::PollMsg, first);
        assert_eq!(got, want);
        staged.push((first, got.start));

        for step in 0..g.usize(1, 40) {
            match g.usize(0, 2) {
                // Explicit-start stage: Result parity with Timeline::reserve.
                0 => {
                    let owner = TaskId(500_000 + step as u64);
                    let start = SimTime::from_micros(g.u64(0, 80_000));
                    let dur = SimDuration::from_micros(g.u64(1, 8_000));
                    let kind = random_kind(g);
                    let got = plan.stage_link(&st, start, dur, kind, owner);
                    let want = reference.reserve(start, dur, kind, owner);
                    assert_eq!(got.is_ok(), want.is_ok(), "stage_link parity at step {step}");
                    if let Ok(w) = got {
                        assert_eq!(w, want.unwrap());
                        staged.push((owner, w.start));
                    }
                }
                // Earliest-fit stage: exact window parity.
                1 => {
                    let owner = TaskId(600_000 + step as u64);
                    let not_before = SimTime::from_micros(g.u64(0, 80_000));
                    let dur = SimDuration::from_micros(g.u64(1, 8_000));
                    let kind = random_kind(g);
                    let got = plan.stage_link_earliest(&st, not_before, dur, kind, owner);
                    let want = reference.reserve_earliest(not_before, dur, kind, owner);
                    assert_eq!(got, want, "stage_link_earliest parity at step {step}");
                    staged.push((owner, got.start));
                }
                // Unstage a random staged (or base) slot: bool parity with
                // Timeline::release.
                _ => {
                    if staged.is_empty() {
                        continue;
                    }
                    let idx = g.usize(0, staged.len() - 1);
                    let (owner, start) = staged.swap_remove(idx);
                    let got = plan.unstage_link_at(owner, start);
                    let want = reference.release(start, owner);
                    assert_eq!(got, want, "unstage parity at step {step}");
                }
            }
            let view = plan.link_view(&st);
            assert!(
                view.same_reservations(&reference),
                "pooled scratch diverged from fresh clone at step {step}"
            );
            view.check_invariants().unwrap();
        }

        // Dropping the plan must restore the committed calendar exactly.
        drop(plan);
        assert!(st.link().same_reservations(&pristine));
        st.link().check_invariants().unwrap();
    });
}

/// A timeline returned to the pool must leak nothing to its next
/// borrower: after a heavily-staged plan is dropped, a new plan's view is
/// exactly `base + its own ops`, and a state mutation between drop and
/// reopen (version bump) must keep stale pool entries from surfacing.
#[test]
fn dropped_plan_leaks_nothing_to_the_next_borrower() {
    run("pool leakage", 150, |g| {
        let (mut st, _base) = state_with_base_slots(g);
        let pristine = st.link().clone();

        // Heavily stage, including some unstages, then drop without
        // committing.
        {
            let mut plan = PlacementPlan::new(&st);
            let mut mine = Vec::new();
            for i in 0..g.usize(5, 25) {
                let owner = TaskId(700_000 + i as u64);
                let w = plan.stage_link_earliest(
                    &st,
                    SimTime::from_micros(g.u64(0, 60_000)),
                    SimDuration::from_micros(g.u64(1, 6_000)),
                    random_kind(g),
                    owner,
                );
                mine.push((owner, w.start));
            }
            for _ in 0..g.usize(0, 5) {
                let idx = g.usize(0, mine.len() - 1);
                let (owner, start) = mine.swap_remove(idx);
                assert!(plan.unstage_link_at(owner, start));
            }
        }
        assert!(st.link().same_reservations(&pristine));

        // Next borrower (pool hit): one probe op, nothing else visible.
        {
            let mut plan = PlacementPlan::new(&st);
            let probe = TaskId(800_000);
            let dur = SimDuration::from_micros(123);
            let got = plan.stage_link_earliest(&st, SimTime::ZERO, dur, SlotKind::PollMsg, probe);
            let mut want = pristine.clone();
            let ww = want.reserve_earliest(SimTime::ZERO, dur, SlotKind::PollMsg, probe);
            assert_eq!(got, ww);
            assert!(
                plan.link_view(&st).same_reservations(&want),
                "dropped plan's ops leaked into the next borrower's view"
            );
        }

        // Mutate the committed state: the version bump invalidates pooled
        // timelines, so a fresh plan must see the new slot, never a stale
        // pooled snapshot.
        let extra = TaskId(810_000);
        st.charge_link_message(
            SimTime::ZERO,
            SimDuration::from_micros(777),
            SlotKind::StateUpdate,
            extra,
        );
        let after = st.link().clone();
        {
            let mut plan = PlacementPlan::new(&st);
            let probe = TaskId(820_000);
            let dur = SimDuration::from_micros(55);
            let got = plan.stage_link_earliest(&st, SimTime::ZERO, dur, SlotKind::PollMsg, probe);
            let mut want = after.clone();
            let ww = want.reserve_earliest(SimTime::ZERO, dur, SlotKind::PollMsg, probe);
            assert_eq!(got, ww);
            assert!(
                plan.link_view(&st).same_reservations(&want),
                "stale pooled timeline surfaced after a state version bump"
            );
        }
        assert!(st.link().same_reservations(&after));
    });
}

// ---------------------------------------------------------------------
// Availability index (resources::avail)
// ---------------------------------------------------------------------

fn t_ms(ms: u64) -> SimTime {
    SimTime::from_millis(ms)
}

/// Commit one placement through the plan door — the only public write path
/// onto a device's core calendar.
fn commit_placement(
    st: &mut NetworkState,
    device: u32,
    start: u64,
    end: u64,
    cores: u32,
) -> TaskId {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(0),
        source: DeviceId(0),
        priority: Priority::Low,
        deadline: t_ms(end),
        spawn: SimTime::ZERO,
        request: None,
    });
    let mut plan = PlacementPlan::new(st);
    plan.stage_placement(
        st,
        Allocation {
            task: id,
            device: DeviceId(device),
            window: Window::new(t_ms(start), t_ms(end)),
            cores,
            offloaded: false,
        },
    )
    .expect("test placement fits");
    st.apply(plan).expect("test placement commits");
    id
}

/// The settled-device lemma the availability index's fast path rests on:
/// once a calendar's last reservation has ended (windows are half-open),
/// the device is completely idle — zero usage, immediate availability at
/// full capacity, zero peak over any later window — under arbitrary
/// reserve/remove sequences.
#[test]
fn settled_device_lemma_holds_under_random_ops() {
    run("settled-device lemma", 250, |g| {
        let capacity = g.u64(1, 8) as u32;
        let mut ct = CoreTimeline::new(capacity);
        let mut live: Vec<TaskId> = Vec::new();
        for step in 0..g.usize(1, 30) {
            if g.bool(0.7) {
                let start = SimTime::from_micros(g.u64(0, 50_000));
                let dur = SimDuration::from_micros(g.u64(1, 20_000));
                let w = Window::from_duration(start, dur);
                let cores = g.u64(1, capacity as u64) as u32;
                let id = TaskId(step as u64);
                if ct.reserve(w, cores, id, w.end, true).is_ok() {
                    live.push(id);
                }
            } else if !live.is_empty() {
                let idx = g.usize(0, live.len() - 1);
                assert_eq!(ct.remove_task(live.swap_remove(idx)), 1);
            }
            let settle = ct.last_end().unwrap_or(SimTime::ZERO);
            for off in [0u64, 1, 1_000, 100_000] {
                let t = SimTime::from_micros(settle.as_micros() + off);
                assert_eq!(ct.usage_at(t), 0, "settled at {settle}, usage at {t} nonzero");
                assert_eq!(
                    ct.earliest_availability(t, capacity),
                    Some(t),
                    "settled device must be available at full capacity immediately"
                );
                let horizon = SimTime::from_micros(t.as_micros() + g.u64(1, 50_000));
                assert_eq!(
                    ct.peak_usage_in(&Window::new(t, horizon)),
                    0,
                    "settled device must show zero peak over any later window"
                );
            }
        }
    });
}

/// `avail::index_for` must serve the same `Rc` for an unchanged snapshot,
/// rebuild after ANY `NetworkState` mutation (the `(uid, version)` cache
/// key makes stale entries unreachable), and — with the index enabled, its
/// process default — produce rescue candidates tuple-identical to the
/// direct per-device scan recomputed from the public state API.
#[test]
fn availability_index_cache_invalidates_and_matches_direct_scan() {
    run("index_for ≡ public-API direct scan", 100, |g| {
        let mut cfg = SystemConfig::default();
        cfg.devices = g.usize(2, 8);
        let mut st = NetworkState::new(&cfg);
        let mut live: Vec<(TaskId, u32)> = Vec::new();
        for step in 0..g.usize(1, 20) {
            // One random public-API mutation.
            match g.usize(0, 4) {
                0 | 1 => {
                    let d = g.u64(0, cfg.devices as u64 - 1) as u32;
                    if st.device_is_up(DeviceId(d)) {
                        let start = g.u64(0, 2_000);
                        let end = start + g.u64(1, 2_000);
                        let cores = g.u64(1, 2) as u32;
                        let w = Window::new(t_ms(start), t_ms(end));
                        if st.device(DeviceId(d)).fits(&w, cores) {
                            let id = commit_placement(&mut st, d, start, end, cores);
                            live.push((id, d));
                        }
                    }
                }
                2 => {
                    if !live.is_empty() {
                        let (id, _) = live.swap_remove(g.usize(0, live.len() - 1));
                        if g.bool(0.5) {
                            st.complete_task(id, t_ms(g.u64(0, 4_000)));
                        } else {
                            st.fail_task(id, FailReason::Violated, t_ms(g.u64(0, 4_000)));
                        }
                    }
                }
                3 => {
                    let d = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                    if st.device_is_up(d) && g.bool(0.3) {
                        st.mark_device_down(d, t_ms(g.u64(0, 4_000)));
                        live.retain(|&(_, dev)| dev != d.0);
                    } else {
                        st.set_device_health(d, DeviceHealth::Up);
                    }
                }
                _ => st.prune_before(t_ms(g.u64(0, 3_000))),
            }

            // Unchanged snapshot ⇒ cache hit (the very same Rc).
            let a = avail::index_for(&st);
            let b = avail::index_for(&st);
            assert!(Rc::ptr_eq(&a, &b), "same (uid, version) must be a cache hit");

            // Any mutation — even one that never touches a device calendar —
            // bumps the version and forces a rebuild to an equal-value index.
            let v = st.version();
            st.charge_link_message(
                SimTime::ZERO,
                SimDuration::from_micros(1 + step as u64),
                SlotKind::PollMsg,
                TaskId(5_000_000 + step as u64),
            );
            assert!(st.version() > v, "every mutating method bumps the version");
            let c = avail::index_for(&st);
            assert!(!Rc::ptr_eq(&a, &c), "version bump must invalidate the cache");
            assert_eq!(a.entries(), c.entries(), "a link charge changes no device calendar");

            // Indexed rescue candidates ≡ the direct scan recomputed from
            // the public state API (same multiset of (peak, device) tuples).
            let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
            let ws = g.u64(0, 4_000);
            let window = Window::new(t_ms(ws), t_ms(ws + g.u64(1, 2_000)));
            let mut indexed = avail::rescue_candidates(&st, source, &window);
            let mut direct: Vec<(u32, u32)> = st
                .up_devices()
                .filter(|&d| d != source)
                .map(|d| (st.device(d).peak_usage_in(&window), d.0))
                .collect();
            indexed.sort_unstable();
            direct.sort_unstable();
            assert_eq!(indexed, direct, "indexed scan diverged from the direct scan");
        }
    });
}
