//! Flight-recorder integration tests: journal bit-identity across engines
//! and shard counts, lifecycle-automaton conservation against
//! `ScenarioMetrics`, the tracing-off byte-identity guarantee, and export
//! smoke. Every `TraceEventKind` variant is exercised by name here — the
//! `obs_door` test greps this file to keep that exhaustive.
//!
//! The recorder toggle (`pats::obs::enable`) is process-wide, so every test
//! in this binary serialises behind one mutex: a toggle flipped mid-run
//! from a sibling test could otherwise tear a traced/untraced comparison.

use std::collections::BTreeMap;
use std::sync::Mutex;

use pats::config::{EngineKind, SystemConfig};
use pats::coordinator::Controller;
use pats::metrics::ScenarioMetrics;
use pats::obs::{self, decompose, export, TraceEvent, TraceEventKind, TraceJournal};
use pats::scheduler::PatsScheduler;
use pats::shard::ControlPlane;
use pats::sim::{run_scenario_dynamic, run_with_surface_dynamic};
use pats::task::{DeviceId, Priority, TaskId};
use pats::time::SimTime;
use pats::trace::{ChurnEvent, ChurnScript, Distribution, Trace};

static GATE: Mutex<()> = Mutex::new(());

fn seed_cfg() -> SystemConfig {
    let mut cfg = SystemConfig::default();
    cfg.frames = 80; // 20 cycles over the paper's 4-device topology
    cfg
}

fn churn_script() -> ChurnScript {
    ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(45.0), ChurnEvent::Drain(DeviceId(2))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ])
}

/// Run one scenario with the recorder armed; returns the metrics and the
/// extracted journal. Callers must hold the GATE.
fn traced_run(
    cfg: &SystemConfig,
    trace: &Trace,
    churn: &ChurnScript,
    label: &str,
) -> (ScenarioMetrics, TraceJournal) {
    obs::enable(true);
    let res = run_scenario_dynamic(cfg, trace, churn, label);
    obs::enable(false);
    let _ = obs::take_recorded();
    (res.metrics, res.trace.expect("armed run must extract a journal"))
}

/// Validate one journal as a set of lifecycle-automaton runs: per task (in
/// canonical order) admission comes first, placement precedes execution,
/// transfers only happen to placed tasks, and exactly one terminal event
/// closes the life. Returns per-task (class, completed).
///
/// Transfers are reserved-link artifacts: a late input can arrive after
/// the window was already violated, and a preempted task's reserved
/// transfer still occupies the link after the victim terminally failed —
/// so transfer events are exempt from the nothing-after-terminal rule.
fn check_lifecycle(journal: &TraceJournal) -> BTreeMap<TaskId, (Priority, bool)> {
    let mut per_task: BTreeMap<TaskId, Vec<&TraceEvent>> = BTreeMap::new();
    for ev in &journal.events {
        match ev.task {
            Some(t) => per_task.entry(t).or_default().push(ev),
            None => assert_eq!(
                ev.kind,
                TraceEventKind::Migrate,
                "Migrate is the only task-less event"
            ),
        }
    }
    let mut out = BTreeMap::new();
    for (task, evs) in &per_task {
        let mut admitted = false;
        let mut class = None;
        let mut placed = 0usize;
        let mut transfer_open = false;
        let mut exec_open = false;
        let mut exec_seen = false;
        let mut terminal: Option<bool> = None;
        for ev in evs {
            match ev.kind {
                TraceEventKind::Admit => {
                    assert!(!admitted, "{task:?} admitted twice");
                    assert!(terminal.is_none(), "{task:?}: Admit after terminal");
                    admitted = true;
                    class = ev.class;
                }
                TraceEventKind::Spill => {
                    assert!(admitted, "{task:?}: Spill before Admit");
                    assert!(terminal.is_none(), "{task:?}: Spill after terminal");
                    assert_eq!(placed, 0, "{task:?}: spilled after a placement");
                }
                TraceEventKind::Place | TraceEventKind::Rescue => {
                    assert!(admitted, "{task:?}: placed before Admit");
                    assert!(terminal.is_none(), "{task:?}: placed after terminal");
                    placed += 1;
                }
                TraceEventKind::Degrade => {
                    assert!(placed > 0, "{task:?}: Degrade without a placement");
                }
                TraceEventKind::Preempt | TraceEventKind::Evict => {
                    // Evict also hits queued (never-placed) workstealer
                    // orphans, so only admission is required.
                    assert!(admitted, "{task:?}: stalled before Admit");
                    assert!(terminal.is_none(), "{task:?}: stalled after terminal");
                }
                TraceEventKind::TransferStart => {
                    assert!(placed > 0, "{task:?}: transfer without a placement");
                    assert!(!transfer_open, "{task:?}: nested transfer");
                    transfer_open = true;
                }
                TraceEventKind::TransferEnd => {
                    assert!(transfer_open, "{task:?}: TransferEnd without start");
                    transfer_open = false;
                }
                TraceEventKind::ExecStart => {
                    assert!(placed > 0, "{task:?}: ExecStart without a placement");
                    assert!(!exec_seen, "{task:?}: executed twice");
                    assert!(terminal.is_none(), "{task:?}: ExecStart after terminal");
                    exec_open = true;
                    exec_seen = true;
                }
                TraceEventKind::ExecEnd => {
                    assert!(exec_open, "{task:?}: ExecEnd without start");
                    exec_open = false;
                }
                TraceEventKind::Complete => {
                    assert!(exec_seen, "{task:?}: Complete without execution");
                    assert!(terminal.is_none(), "{task:?}: two terminal events");
                    terminal = Some(true);
                }
                TraceEventKind::Fail => {
                    assert!(admitted, "{task:?}: Fail before Admit");
                    assert!(terminal.is_none(), "{task:?}: two terminal events");
                    terminal = Some(false);
                }
                TraceEventKind::Migrate => {
                    unreachable!("{task:?}: Migrate carries no task")
                }
            }
        }
        assert!(admitted, "{task:?} has events but no Admit");
        let completed =
            terminal.unwrap_or_else(|| panic!("{task:?} has no terminal event"));
        let class = class.unwrap_or_else(|| panic!("{task:?}: Admit without a class"));
        out.insert(*task, (class, completed));
    }
    out
}

#[test]
fn tracing_off_output_is_byte_identical_to_untraced() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = seed_cfg();
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    obs::enable(false);
    let off = run_scenario_dynamic(&cfg, &trace, &ChurnScript::none(), "seed");
    assert!(off.trace.is_none(), "disarmed run must not build a journal");
    let (on_metrics, journal) = traced_run(&cfg, &trace, &ChurnScript::none(), "seed");
    assert!(!journal.events.is_empty());
    // Tracing adds the `trace` block and nothing else: stripped of it, the
    // traced run's deterministic JSON is byte-identical to the untraced
    // run's, and the text report is a strict prefix extension.
    assert_eq!(
        off.metrics.deterministic_json().to_string_pretty(),
        on_metrics.deterministic_json().without_keys(&["trace"]).to_string_pretty(),
        "tracing perturbed a simulated counter"
    );
    assert!(off.metrics.trace.is_none());
    assert!(on_metrics.trace.is_some());
    assert!(
        on_metrics.render_text().starts_with(&off.metrics.render_text()),
        "tracing rewrote the text report instead of appending to it"
    );
}

#[test]
fn journals_are_bit_identical_across_engines_and_shard_counts() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let cfg0 = seed_cfg();
    let trace = Trace::generate(Distribution::Uniform, cfg0.devices, cfg0.frames, cfg0.seed);
    let script = churn_script();
    let mut journals: Vec<(String, TraceJournal)> = Vec::new();
    for engine in [EngineKind::Serial, EngineKind::Parallel] {
        for k in [1usize, 2, 4] {
            let mut cfg = cfg0.clone();
            cfg.sharding.engine = engine;
            cfg.sharding.shards = k;
            let (_, journal) = traced_run(&cfg, &trace, &script, "eq");
            journals.push((format!("{engine}, shards={k}"), journal));
        }
    }
    let (ref_ctx, reference) = &journals[0];
    assert!(!reference.events.is_empty());
    assert_eq!(reference.dropped, 0);
    for (ctx, journal) in &journals[1..] {
        assert_eq!(
            reference, journal,
            "journal of ({ctx}) differs from ({ref_ctx})"
        );
    }
}

#[test]
fn one_shard_plane_journal_matches_the_raw_controller() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = seed_cfg();
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    let script = churn_script();

    obs::enable(true);
    let controller = Controller::new(cfg.clone(), PatsScheduler::from_config(&cfg));
    let (raw, _c) = run_with_surface_dynamic(&cfg, &trace, &script, "raw", controller);
    let plane: ControlPlane<PatsScheduler> = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let (pl, _p) = run_with_surface_dynamic(&cfg, &trace, &script, "k1", plane);
    obs::enable(false);
    let _ = obs::take_recorded();

    let raw_journal = raw.trace.expect("raw journal");
    let plane_journal = pl.trace.expect("plane journal");
    assert_eq!(raw_journal, plane_journal, "K=1 plane journal drifted from the raw controller");
    // A 1-shard plane has no sibling to spill to and no rebalancer moves:
    // the shard-only event kinds must be absent.
    assert!(
        !raw_journal
            .events
            .iter()
            .any(|e| matches!(e.kind, TraceEventKind::Spill | TraceEventKind::Migrate)),
        "shard-only events in an unsharded journal"
    );
}

#[test]
fn lifecycle_conservation_on_the_seed_scenario() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = seed_cfg();
    // Weighted-4 on the seed topology: the workload the sim suite already
    // pins as reliably preemption-triggering, so the Preempt identity below
    // is a real check and not vacuous.
    let trace = Trace::generate(Distribution::Weighted(4), cfg.devices, cfg.frames, cfg.seed);
    let (m, journal) = traced_run(&cfg, &trace, &ChurnScript::none(), "seed");
    let lives = check_lifecycle(&journal);

    let admitted_hp = lives.values().filter(|(c, _)| *c == Priority::High).count() as u64;
    let admitted_lp = lives.values().filter(|(c, _)| *c == Priority::Low).count() as u64;
    let done_hp = lives.values().filter(|&&(c, ok)| c == Priority::High && ok).count() as u64;
    let done_lp = lives.values().filter(|&&(c, ok)| c == Priority::Low && ok).count() as u64;
    assert_eq!(admitted_hp, m.hp_generated, "one Admit per generated HP task");
    assert_eq!(admitted_lp, m.lp_generated, "one Admit per generated LP task");
    assert_eq!(done_hp, m.hp_completed, "one Complete per completed HP task");
    assert_eq!(done_lp, m.lp_completed, "one Complete per completed LP task");

    let preempts =
        journal.events.iter().filter(|e| e.kind == TraceEventKind::Preempt).count() as u64;
    assert_eq!(preempts, m.preemptions, "one Preempt per committed preemption");
    assert!(m.preemptions > 0, "the seed scenario must exercise preemption");

    // The decomposition agrees with the raw automaton pass.
    let per_task = decompose(&journal.events);
    assert_eq!(per_task.len(), lives.len());
    for (task, tt) in &per_task {
        assert_eq!((tt.class, tt.lat.completed), lives[task]);
    }

    // The folded stats rode into ScenarioMetrics bit-exactly.
    let stats = m.trace.as_ref().expect("trace stats attached");
    assert_eq!(stats.events, journal.events.len() as u64);
    assert_eq!(stats.dropped, journal.dropped);
    assert_eq!(stats.hp.tasks, m.hp_generated);
    assert_eq!(stats.lp.tasks, m.lp_generated);
    assert_eq!(stats.hp.completed, m.hp_completed);
    assert_eq!(stats.lp.completed, m.lp_completed);
    // Every missed frame is blamed on exactly one dominant component.
    assert_eq!(stats.miss.frames, m.frames_failed_hp + m.frames_failed_lp);
    let lane_sum = stats.miss.admission
        + stats.miss.link
        + stats.miss.compute
        + stats.miss.preempt
        + stats.miss.rescue;
    assert_eq!(stats.miss.frames, lane_sum);
}

#[test]
fn lifecycle_conservation_under_churn() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = seed_cfg();
    cfg.frames = 160;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let (m, journal) = traced_run(&cfg, &trace, &churn_script(), "churn");
    assert!(m.failures_detected > 0, "the script must actually kill a device");
    let lives = check_lifecycle(&journal);

    let admitted_hp = lives.values().filter(|(c, _)| *c == Priority::High).count() as u64;
    let admitted_lp = lives.values().filter(|(c, _)| *c == Priority::Low).count() as u64;
    assert_eq!(admitted_hp, m.hp_generated);
    assert_eq!(admitted_lp, m.lp_generated);
    let done_hp = lives.values().filter(|&&(c, ok)| c == Priority::High && ok).count() as u64;
    let done_lp = lives.values().filter(|&&(c, ok)| c == Priority::Low && ok).count() as u64;
    assert_eq!(done_hp, m.hp_completed);
    assert_eq!(done_lp, m.lp_completed);

    // One Evict per churn orphan, one Rescue per relocated HP orphan.
    let evicts = journal.events.iter().filter(|e| e.kind == TraceEventKind::Evict).count() as u64;
    assert_eq!(evicts, m.tasks_orphaned(), "one Evict per orphaned task");
    let rescues =
        journal.events.iter().filter(|e| e.kind == TraceEventKind::Rescue).count() as u64;
    assert_eq!(rescues, m.hp_rescued, "one Rescue per relocated HP orphan");

    let stats = m.trace.as_ref().expect("trace stats attached");
    assert_eq!(stats.miss.frames, m.frames_failed_hp + m.frames_failed_lp);
}

#[test]
fn export_round_trip_covers_the_recorded_runs() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let cfg = seed_cfg();
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    obs::enable(true);
    let res = run_scenario_dynamic(&cfg, &trace, &ChurnScript::none(), "export-seed");
    obs::enable(false);
    let runs = obs::take_recorded();
    assert_eq!(runs.len(), 1, "finalize retains exactly one run");
    assert_eq!(runs[0].label, "export-seed");
    assert!(runs[0].summary.contains("deadline-miss attribution"));
    let journal = res.trace.expect("journal");
    assert_eq!(runs[0].journal, journal, "retained journal == extracted journal");

    let jsonl = export::jsonl(&runs);
    assert_eq!(jsonl.lines().count(), journal.events.len(), "one JSONL line per event");
    assert!(jsonl.contains("\"ev\":\"admit\""));
    let chrome = export::chrome(&runs);
    assert!(chrome.starts_with("{\"traceEvents\":["));

    let dir = std::env::temp_dir().join("pats_trace_export_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("out.json");
    let (chrome_path, jsonl_path) =
        export::write_files(path.to_str().unwrap(), &runs).unwrap();
    assert!(std::fs::metadata(&chrome_path).unwrap().len() > 0);
    assert!(std::fs::metadata(&jsonl_path).unwrap().len() > 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn ring_bound_censors_but_never_corrupts() {
    let _g = GATE.lock().unwrap_or_else(|e| e.into_inner());
    let mut cfg = seed_cfg();
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    let (_, full) = traced_run(&cfg, &trace, &ChurnScript::none(), "full");
    cfg.obs.ring_capacity = 64; // far below the seed scenario's event count
    let (m, bounded) = traced_run(&cfg, &trace, &ChurnScript::none(), "bounded");
    // Drop-newest: every emission is either retained or counted in the
    // dropped tally, never both and never lost — the bounded journal plus
    // its tally reconstructs the unbounded event count exactly.
    assert!(bounded.dropped > 0, "the tiny ring must overflow");
    assert_eq!(
        bounded.events.len() as u64 + bounded.dropped,
        full.events.len() as u64,
        "retained + dropped must equal the unbounded event count"
    );
    let stats = m.trace.as_ref().unwrap();
    assert_eq!(stats.dropped, bounded.dropped);
}
