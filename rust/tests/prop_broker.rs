//! Randomized property tests on the bandwidth broker: lease fractions
//! never oversubscribe the physical medium, every shard keeps its floor,
//! and re-leasing an in-use partition never disturbs committed link
//! reservations (fingerprint-checked — `NetworkState::fingerprint` hashes
//! the committed slot windows, which are stored as explicit instants and
//! must therefore survive any partition change).

use pats::config::SystemConfig;
use pats::coordinator::ControlSurface;
use pats::scheduler::PatsScheduler;
use pats::shard::{compute_leases, ControlPlane};
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;
use pats::util::prop::{run, Gen};

fn random_demand(g: &mut Gen, k: usize) -> Vec<f64> {
    (0..k)
        .map(|_| if g.bool(0.25) { 0.0 } else { g.f64(0.0, 1.0e6) })
        .collect()
}

#[test]
fn leases_sum_to_at_most_one_and_respect_the_floor() {
    run("lease invariants", 400, |g| {
        let k = g.usize(1, 12);
        let floor = g.f64(0.001, 1.0);
        let demand = random_demand(g, k);
        let leases = compute_leases(&demand, floor);
        assert_eq!(leases.len(), k);
        let sum: f64 = leases.iter().sum();
        assert!(sum <= 1.0 + 1e-9, "leases {leases:?} oversubscribe: sum {sum}");
        // The configured floor only fits K times if it is at most 1/K; the
        // broker clamps it so K floors always tile the medium.
        let eff_floor = floor.min(1.0 / k as f64);
        for (s, &lease) in leases.iter().enumerate() {
            assert!(lease.is_finite(), "shard {s} lease {lease} not finite");
            assert!(
                lease >= eff_floor - 1e-9,
                "shard {s} lease {lease} starves the {eff_floor} floor"
            );
            assert!(lease > 0.0 && lease <= 1.0 + 1e-9, "shard {s} lease {lease}");
        }
    });
}

#[test]
fn zero_demand_reverts_to_the_even_static_split() {
    run("zero demand", 100, |g| {
        let k = g.usize(1, 12);
        let floor = g.f64(0.001, 1.0);
        let leases = compute_leases(&vec![0.0; k], floor);
        for &lease in &leases {
            assert_eq!(lease.to_bits(), (1.0 / k as f64).to_bits());
        }
    });
}

#[test]
fn lease_computation_is_deterministic() {
    run("lease determinism", 100, |g| {
        let k = g.usize(1, 12);
        let floor = g.f64(0.001, 1.0);
        let demand = random_demand(g, k);
        let a = compute_leases(&demand, floor);
        let b = compute_leases(&demand, floor);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.to_bits(), y.to_bits(), "same demand, different leases");
        }
    });
}

/// Load a plane with a random mix of HP/LP admissions so its link
/// calendars hold real committed reservations.
fn random_workload(g: &mut Gen, plane: &mut ControlPlane<PatsScheduler>, cfg: &SystemConfig) {
    let deadline = SimTime::ZERO + cfg.frame_deadline();
    let requests = g.usize(1, 2 * cfg.devices);
    for i in 0..requests {
        let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
        if g.bool(0.3) {
            let _ = ControlSurface::handle_hp_request(
                plane,
                FrameId(i as u64),
                source,
                SimTime::ZERO,
            );
        } else {
            let n = g.u64(1, 4) as u8;
            let _ = ControlSurface::handle_lp_request(
                plane,
                FrameId(i as u64),
                source,
                n,
                deadline,
                SimTime::ZERO,
            );
        }
    }
}

#[test]
fn re_leasing_an_in_use_partition_never_invalidates_committed_reservations() {
    run("re-lease safety", 60, |g| {
        let shards = *g.pick(&[2usize, 3, 4, 8]);
        let mut cfg = SystemConfig::default();
        cfg.devices = shards * g.usize(2, 4);
        cfg.sharding.shards = shards;
        cfg.sharding.broker.enabled = true;
        let mut plane: ControlPlane<PatsScheduler> =
            ControlPlane::new(&cfg, PatsScheduler::from_config);
        random_workload(g, &mut plane, &cfg);
        plane.check_invariants().unwrap();
        let before = ControlSurface::fingerprint(&plane);

        // A burst of arbitrary (valid) re-leases against the loaded plane.
        for _ in 0..g.usize(1, 5) {
            let leases = compute_leases(&random_demand(g, shards), g.f64(0.01, 1.0));
            plane.apply_leases(&leases);
            let sum: f64 = plane.leases().iter().sum();
            assert!(sum <= 1.0 + 1e-9, "plane accepted oversubscribed leases");
        }

        assert_eq!(
            ControlSurface::fingerprint(&plane),
            before,
            "re-leasing disturbed committed link reservations"
        );
        plane.check_invariants().unwrap();

        // The re-leased plane still serves admissions cleanly.
        let deadline = SimTime::ZERO + cfg.frame_deadline();
        let _ = ControlSurface::handle_lp_request(
            &mut plane,
            FrameId(99_999),
            DeviceId(0),
            2,
            deadline,
            SimTime::ZERO,
        );
        plane.check_invariants().unwrap();
    });
}

#[test]
fn broker_epochs_keep_the_lease_invariant_under_random_traffic() {
    run("epoch invariants", 40, |g| {
        let shards = *g.pick(&[2usize, 4]);
        let mut cfg = SystemConfig::default();
        cfg.devices = 4 * shards;
        cfg.sharding.shards = shards;
        cfg.sharding.broker.enabled = true;
        cfg.sharding.rebalance.enabled = g.bool(0.5);
        let floor = cfg.sharding.broker.floor;
        let mut plane: ControlPlane<PatsScheduler> =
            ControlPlane::new(&cfg, PatsScheduler::from_config);
        let mut now = SimTime::ZERO;
        for round in 0..g.usize(1, 4) {
            let deadline = now + cfg.frame_deadline();
            for i in 0..g.usize(1, cfg.devices) {
                let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                let _ = ControlSurface::handle_lp_request(
                    &mut plane,
                    FrameId((round * 1_000 + i) as u64),
                    source,
                    g.u64(1, 4) as u8,
                    deadline,
                    now,
                );
            }
            now = now + pats::time::SimDuration::from_secs_f64(g.f64(1.0, 120.0));
            ControlSurface::epoch(&mut plane, now);
            let sum: f64 = plane.leases().iter().sum();
            assert!(sum <= 1.0 + 1e-9, "epoch oversubscribed the medium: {sum}");
            for (s, &lease) in plane.leases().iter().enumerate() {
                assert!(
                    lease >= floor.min(1.0 / shards as f64) - 1e-9,
                    "epoch starved shard {s}: lease {lease}"
                );
            }
        }
        plane.check_invariants().unwrap();
    });
}
