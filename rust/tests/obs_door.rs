//! Grep-enforced exhaustiveness door for the flight recorder: adding a
//! `TraceEventKind` variant must extend the JSONL serializer, the Chrome
//! exporter, and the lifecycle integration tests in the same change. The
//! compiler already forces the two `match`es to be total — these checks
//! additionally forbid satisfying it with a wildcard arm and keep the
//! integration suite exercising every variant by name.

use std::fs;

/// Variant identifiers, mirrored from `TraceEventKind::ALL`. Deliberately a
/// string list: this test greps source text, and a new variant that is not
/// added here trips the count check against `ALL` below.
const VARIANTS: &[&str] = &[
    "Admit",
    "Spill",
    "Preempt",
    "Evict",
    "Place",
    "Rescue",
    "Degrade",
    "Migrate",
    "TransferStart",
    "TransferEnd",
    "ExecStart",
    "ExecEnd",
    "Complete",
    "Fail",
];

fn repo_file(rel: &str) -> String {
    let path = format!("{}/{}", env!("CARGO_MANIFEST_DIR"), rel);
    fs::read_to_string(&path).unwrap_or_else(|e| panic!("reading {path}: {e}"))
}

#[test]
fn variant_list_matches_the_enum() {
    assert_eq!(
        VARIANTS.len(),
        pats::obs::TraceEventKind::ALL.len(),
        "update VARIANTS when TraceEventKind grows"
    );
    for (name, kind) in VARIANTS.iter().zip(pats::obs::TraceEventKind::ALL) {
        assert_eq!(format!("{kind:?}"), *name, "VARIANTS must mirror ALL's order");
    }
}

#[test]
fn every_variant_is_matched_in_both_exporters_without_wildcards() {
    let src = repo_file("rust/src/obs/export.rs");
    let split = src
        .find("fn chrome_cat")
        .expect("export.rs lost its chrome_cat exporter");
    let (jsonl_half, chrome_half) = src.split_at(split);
    for v in VARIANTS {
        let needle = format!("TraceEventKind::{v}");
        assert!(
            jsonl_half.contains(&needle),
            "{needle} is not handled by the JSONL serializer (kind_str)"
        );
        assert!(
            chrome_half.contains(&needle),
            "{needle} is not handled by the Chrome exporter (chrome_cat)"
        );
    }
    assert!(
        !src.contains("_ =>"),
        "export.rs must match trace kinds exhaustively, not via a wildcard arm"
    );
}

#[test]
fn every_variant_is_exercised_by_the_lifecycle_tests() {
    let src = repo_file("rust/tests/trace.rs");
    for v in VARIANTS {
        let needle = format!("TraceEventKind::{v}");
        assert!(
            src.contains(&needle),
            "{needle} never appears in rust/tests/trace.rs — extend the \
             lifecycle automaton for the new variant"
        );
    }
}
