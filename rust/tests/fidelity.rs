//! Multi-fidelity integration properties.
//!
//! 1. **Strict opt-in**: with the `[fidelity]` defaults (single-variant
//!    catalog) — or with variants present but `mode = off` — every scenario
//!    metric is identical to the pre-fidelity behaviour, including the
//!    float summaries to the bit.
//! 2. **Degraded admission picks the highest feasible accuracy**: a
//!    deterministic scene where the full model and the first degraded
//!    variant both miss the deadline, but the second fits.
//! 3. **Conservation**: under degradation *and* churn, every frame ends
//!    exactly one of completed-at-a-variant (full or degraded), failed, or
//!    lost-to-churn; tasks conserve the same way.
//! 4. **Monotonicity**: the four-policy sweep never completes fewer frames
//!    than the `off` policy at any fleet size, and degradation counters
//!    route strictly by the paths each mode permits.

use pats::config::SystemConfig;
use pats::experiments::{fidelity, fidelity_matrix};
use pats::fidelity::{Catalog, Mode, VariantId};
use pats::metrics::ScenarioMetrics;
use pats::scheduler::low_priority::allocate_request;
use pats::scheduler::plan::PlacementPlan;
use pats::sim::run_scenario;
use pats::state::NetworkState;
use pats::task::{
    Allocation, DeviceId, FrameId, LpRequest, Priority, TaskId, TaskSpec, TaskState, Window,
};
use pats::time::SimTime;
use pats::trace::{Distribution, Trace};

fn assert_scenarios_identical(a: &ScenarioMetrics, b: &ScenarioMetrics, what: &str) {
    assert_eq!(a.frames_completed, b.frames_completed, "{what}");
    assert_eq!(a.frames_failed_hp, b.frames_failed_hp, "{what}");
    assert_eq!(a.frames_failed_lp, b.frames_failed_lp, "{what}");
    assert_eq!(a.hp_generated, b.hp_generated, "{what}");
    assert_eq!(a.hp_completed, b.hp_completed, "{what}");
    assert_eq!(a.hp_failed_alloc, b.hp_failed_alloc, "{what}");
    assert_eq!(a.hp_violated, b.hp_violated, "{what}");
    assert_eq!(a.lp_generated, b.lp_generated, "{what}");
    assert_eq!(a.lp_completed, b.lp_completed, "{what}");
    assert_eq!(a.lp_failed_alloc, b.lp_failed_alloc, "{what}");
    assert_eq!(a.lp_failed_preempted, b.lp_failed_preempted, "{what}");
    assert_eq!(a.lp_violated, b.lp_violated, "{what}");
    assert_eq!(a.preemptions, b.preemptions, "{what}");
    assert_eq!(a.realloc_success, b.realloc_success, "{what}");
    assert_eq!(a.lp_offloaded, b.lp_offloaded, "{what}");
    assert_eq!(
        a.lp_set_fractions.mean().to_bits(),
        b.lp_set_fractions.mean().to_bits(),
        "{what}: float summaries must be bit-identical"
    );
}

/// The single-variant default — and a multi-variant catalog under
/// `mode = off` — reproduce the pre-fidelity placements bit-for-bit.
#[test]
fn single_variant_default_is_bit_identical_to_fidelity_off() {
    let mut cfg = SystemConfig::default();
    cfg.frames = 160;
    let trace = Trace::generate(Distribution::Weighted(4), cfg.devices, cfg.frames, cfg.seed);

    // The shipped default: permissive mode, single-variant catalog.
    let baseline = run_scenario(&cfg, &trace, "default").metrics;
    assert_eq!(baseline.degradations(), 0, "nothing to degrade to");
    assert_eq!(baseline.frames_completed_degraded, 0);
    assert_eq!(
        baseline.accuracy_goodput_pct().to_bits(),
        baseline.frame_completion_pct().to_bits(),
        "full fidelity: goodput IS frame completion"
    );

    // Mode off, single catalog.
    let mut off = cfg.clone();
    off.fidelity.mode = Mode::Off;
    let off = run_scenario(&off, &trace, "off").metrics;
    assert_scenarios_identical(&baseline, &off, "mode=off vs default");

    // Demo catalog but mode off: variants exist, nothing may use them.
    let mut gated = cfg.clone();
    gated.fidelity.catalog = Catalog::demo();
    gated.fidelity.mode = Mode::Off;
    let gated = run_scenario(&gated, &trace, "gated").metrics;
    assert_eq!(gated.degradations(), 0);
    assert_scenarios_identical(&baseline, &gated, "demo catalog + mode=off vs default");
}

fn register_lp(st: &mut NetworkState, source: u32, deadline_s: f64, rid: Option<u64>) -> TaskId {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(0),
        source: DeviceId(source),
        priority: Priority::Low,
        deadline: SimTime::from_secs_f64(deadline_s),
        spawn: SimTime::ZERO,
        request: rid.map(pats::task::RequestId),
    });
    id
}

fn wall(st: &mut NetworkState, dev: u32, until_s: f64) {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(99),
        source: DeviceId(dev),
        priority: Priority::High,
        deadline: SimTime::from_secs_f64(600.0),
        spawn: SimTime::ZERO,
        request: None,
    });
    let mut plan = PlacementPlan::new(st);
    plan.stage_placement(st, Allocation {
        task: id,
        device: DeviceId(dev),
        window: Window::new(SimTime::ZERO, SimTime::from_secs_f64(until_s)),
        cores: 4,
        offloaded: false,
    })
    .unwrap();
    st.apply(plan).unwrap();
}

/// Every device is walled off by non-preemptible work until t = 10 s and
/// the request deadline is one frame period (18.86 s). The full model
/// (slot ≈ 17.4 s) and the first degraded variant (slot ≈ 10.6 s) both
/// miss the deadline from the t = 10 s completion point; the second
/// degraded variant (slot ≈ 6.4 s) fits — the admission must commit it,
/// and at nothing less accurate.
#[test]
fn degraded_admission_picks_the_highest_feasible_accuracy() {
    let mut cfg = SystemConfig::default();
    cfg.fidelity.catalog = Catalog::demo();
    cfg.fidelity.mode = Mode::Admission;
    let mut st = NetworkState::new(&cfg);
    for d in 0..4 {
        wall(&mut st, d, 10.0);
    }
    let rid = st.fresh_request_id();
    let task = register_lp(&mut st, 0, 18.86, Some(rid.0));
    st.register_request(LpRequest {
        id: rid,
        frame: FrameId(0),
        source: DeviceId(0),
        deadline: SimTime::from_secs_f64(18.86),
        spawn: SimTime::ZERO,
        tasks: vec![task],
    });

    let out = allocate_request(&mut st, &cfg, rid, SimTime::ZERO);
    assert!(out.fully_allocated(), "the tiny variant must save the task");
    let rec = st.task(task).unwrap();
    assert_eq!(rec.state, TaskState::Allocated);
    assert_eq!(
        rec.variant,
        VariantId(2),
        "v1 cannot meet the deadline, v2 is the highest feasible accuracy"
    );
    let alloc = rec.allocation.as_ref().unwrap();
    assert!(alloc.window.start >= SimTime::from_secs_f64(10.0));
    assert!(alloc.window.end <= SimTime::from_secs_f64(18.86));
    st.check_invariants().unwrap();

    // The same scene under mode=off keeps the paper's behaviour: rejected.
    let mut cfg_off = cfg.clone();
    cfg_off.fidelity.mode = Mode::Off;
    let mut st = NetworkState::new(&cfg_off);
    for d in 0..4 {
        wall(&mut st, d, 10.0);
    }
    let rid = st.fresh_request_id();
    let task = register_lp(&mut st, 0, 18.86, Some(rid.0));
    st.register_request(LpRequest {
        id: rid,
        frame: FrameId(0),
        source: DeviceId(0),
        deadline: SimTime::from_secs_f64(18.86),
        spawn: SimTime::ZERO,
        tasks: vec![task],
    });
    let out = allocate_request(&mut st, &cfg_off, rid, SimTime::ZERO);
    assert!(!out.fully_allocated(), "off: reject-or-fail, as the paper does");
    assert_eq!(st.task(task).unwrap().state, TaskState::Pending);
}

/// The four-policy sweep at two small fleet sizes: frames completed is
/// monotone non-decreasing vs `off`, conservation holds under churn, and
/// the degradation counters route by the paths each mode permits.
#[test]
fn fidelity_sweep_conserves_frames_and_routes_by_mode() {
    let mut cfg = SystemConfig::default();
    cfg.fidelity.cycles = 3;
    cfg.fidelity.crash_pct = 25;
    let sizes = [4usize, 8];
    let rows = fidelity(&cfg, &sizes);
    assert_eq!(rows.len(), sizes.len() * fidelity_matrix().len());

    for &devices in &sizes {
        let row = |tag: &str| {
            rows.iter()
                .find(|r| r.label == format!("{tag}_{devices}"))
                .unwrap_or_else(|| panic!("missing {tag}_{devices}"))
        };
        let off = row("FID_OFF");
        assert_eq!(off.metrics.degradations(), 0, "off never degrades");
        assert_eq!(off.metrics.frames_completed_degraded, 0);

        for r in [off, row("FID_ADM"), row("FID_PRE"), row("FID_FULL")] {
            let m = &r.metrics;
            // Frame conservation: completed (full + degraded are a split of
            // completed), failed, or lost to churn — nothing else.
            assert_eq!(
                m.frames_completed + m.frames_failed_hp + m.frames_failed_lp
                    + m.frames_lost_churn,
                m.frames_total,
                "{}: frame conservation",
                r.label
            );
            assert!(m.frames_completed_degraded <= m.frames_completed, "{}", r.label);
            // Task conservation, churn included.
            assert_eq!(
                m.hp_completed + m.hp_failed_alloc + m.hp_violated + m.hp_lost_churn,
                m.hp_generated,
                "{}: HP conservation",
                r.label
            );
            assert_eq!(
                m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated
                    + m.lp_lost_churn,
                m.lp_generated,
                "{}: LP conservation",
                r.label
            );
            // The accuracy proxy is in (0, 1] per frame.
            assert!(m.accuracy_goodput <= m.frames_completed as f64 + 1e-9, "{}", r.label);
            // Acceptance: degradation never completes fewer frames than the
            // paper's reject-or-fail behaviour on the same scenario.
            assert!(
                m.frames_completed >= off.metrics.frames_completed,
                "{}: {} < off's {}",
                r.label,
                m.frames_completed,
                off.metrics.frames_completed
            );
        }
        // Path gating: admission-only must not touch the victim or rescue
        // paths; admission+preemption must not touch rescue.
        let adm = &row("FID_ADM").metrics;
        assert_eq!(adm.degraded_victim_realloc, 0, "admission-only gates victims");
        assert_eq!(adm.degraded_rescue, 0, "admission-only gates rescue");
        let pre = &row("FID_PRE").metrics;
        assert_eq!(pre.degraded_rescue, 0, "admission+preemption gates rescue");
    }

    // Somewhere in the sweep the degraded paths must actually fire — an
    // over-committed steady workload at 4-task sets leaves plenty of
    // full-fidelity failures to save.
    let total_degradations: u64 = rows.iter().map(|r| r.metrics.degradations()).sum();
    assert!(total_degradations > 0, "the sweep never degraded anything");
}

/// Determinism: the same fidelity scenario twice gives identical metrics.
#[test]
fn fidelity_runs_are_deterministic() {
    let mut cfg = SystemConfig::default();
    cfg.fidelity.cycles = 2;
    let a = fidelity(&cfg, &[4]);
    let b = fidelity(&cfg, &[4]);
    for (ra, rb) in a.iter().zip(&b) {
        assert_eq!(ra.label, rb.label);
        assert_scenarios_identical(&ra.metrics, &rb.metrics, &ra.label);
        assert_eq!(ra.metrics.degradations(), rb.metrics.degradations(), "{}", ra.label);
        assert_eq!(
            ra.metrics.frames_completed_degraded,
            rb.metrics.frames_completed_degraded,
            "{}",
            ra.label
        );
        assert_eq!(
            ra.metrics.accuracy_goodput.to_bits(),
            rb.metrics.accuracy_goodput.to_bits(),
            "{}",
            ra.label
        );
    }
}
