//! Randomized property tests on the scheduling policies: whatever request
//! sequence arrives, the controller's resource invariants must hold and
//! every committed allocation must respect the paper's rules.

use pats::config::SystemConfig;
use pats::coordinator::Controller;
use pats::scheduler::{PatsScheduler, Policy};
use pats::task::{DeviceId, FrameId, Priority, TaskState};
use pats::time::{SimDuration, SimTime};
use pats::util::prop::{run, Gen};
use pats::workstealer::{Mode, Workstealer};

/// Drive a random request mix through a policy; check global invariants
/// after every step.
fn drive<P: Policy>(g: &mut Gen, cfg: &SystemConfig, mut policy: P) {
    let mut controller = Controller::new(cfg.clone(), policy_noop());
    // We bypass Controller's policy (noop) and call the policy under test
    // directly so we can interleave arbitrary events.
    let st = &mut controller.state;
    let mut now = SimTime::ZERO;
    let mut live_hp = Vec::new();
    let mut live_lp = Vec::new();

    for step in 0..g.usize(5, 40) {
        now = now + SimDuration::from_micros(g.u64(1, 3_000_000));
        match g.usize(0, 9) {
            // High-priority request (frequent).
            0..=3 => {
                let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                let id = st.fresh_task_id();
                st.register_task(pats::task::TaskSpec {
                    id,
                    frame: FrameId(step as u64),
                    source,
                    priority: Priority::High,
                    deadline: now + SimDuration::from_secs_f64(cfg.hp_deadline_s),
                    spawn: now,
                    request: None,
                });
                let out = policy.allocate_hp(st, cfg, id, now);
                if let Some(w) = out.window {
                    live_hp.push(id);
                    // HP rules: local to source, 1 core, inside deadline.
                    let rec = st.task(id).unwrap();
                    let alloc = rec.allocation.as_ref().unwrap();
                    assert_eq!(alloc.device, source, "HP must stay on its source");
                    assert_eq!(alloc.cores, 1);
                    assert!(!alloc.offloaded);
                    assert!(w.end <= rec.spec.deadline, "HP window exceeds deadline");
                }
                if let Some(report) = out.preemption {
                    // Victims must be low-priority tasks.
                    let victim = st.task(report.victim).unwrap();
                    assert_eq!(victim.spec.priority, Priority::Low);
                    assert!(victim.preemptions >= 1);
                }
            }
            // Low-priority request.
            4..=6 => {
                let source = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                let n = g.usize(1, 4);
                let rid = st.fresh_request_id();
                let deadline = now + SimDuration::from_secs_f64(cfg.frame_period_s);
                let mut tasks = Vec::new();
                for _ in 0..n {
                    let id = st.fresh_task_id();
                    st.register_task(pats::task::TaskSpec {
                        id,
                        frame: FrameId(step as u64),
                        source,
                        priority: Priority::Low,
                        deadline,
                        spawn: now,
                        request: Some(rid),
                    });
                    tasks.push(id);
                }
                st.register_request(pats::task::LpRequest {
                    id: rid,
                    frame: FrameId(step as u64),
                    source,
                    deadline,
                    spawn: now,
                    tasks,
                });
                let out = policy.allocate_lp(st, cfg, rid, now);
                for p in &out.placements {
                    live_lp.push(p.task);
                    // LP rules: 2 or 4 cores; window within the deadline
                    // (the rash workstealer clips at the deadline instead).
                    assert!(p.cores == 2 || p.cores == 4, "cores {}", p.cores);
                    assert!(p.window.end <= deadline);
                    let rec = st.task(p.task).unwrap();
                    assert_eq!(rec.state, TaskState::Allocated);
                    if p.offloaded {
                        assert_ne!(rec.spec.source, p.device);
                        assert!(p.input_ready.is_some());
                        assert!(p.input_ready.unwrap() <= p.window.start);
                    } else {
                        assert_eq!(rec.spec.source, p.device);
                    }
                }
            }
            // Random completion of a live task.
            7..=8 => {
                let pool = if g.bool(0.5) && !live_hp.is_empty() {
                    &mut live_hp
                } else {
                    &mut live_lp
                };
                if !pool.is_empty() {
                    let idx = g.usize(0, pool.len() - 1);
                    let id = pool.swap_remove(idx);
                    if st.task(id).map(|r| r.state.is_active_allocation()) == Some(true) {
                        st.complete_task(id, now);
                        policy.on_task_end(st, cfg, id, now);
                    }
                }
            }
            // Poll tick (workstealers pull work).
            _ => {
                let dev = DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32);
                for p in policy.poll(st, cfg, dev, now) {
                    live_lp.push(p.task);
                }
            }
        }
        st.check_invariants().unwrap();

        // Global: every device's peak usage within capacity at every
        // reservation start (exhaustive step-function check).
        for d in st.device_ids() {
            let ct = st.device(d);
            for s in ct.slots() {
                assert!(
                    ct.usage_at(s.window.start) <= ct.capacity(),
                    "device {d} over capacity"
                );
            }
        }
    }
}

/// A policy that does nothing (placeholder inside the controller shell).
fn policy_noop() -> PatsScheduler {
    PatsScheduler { preemption: false, reallocate: false, set_aware_victims: false }
}

#[test]
fn scheduler_with_preemption_invariants() {
    run("scheduler+preemption", 60, |g| {
        let cfg = SystemConfig::default();
        drive(g, &cfg, PatsScheduler { preemption: true, reallocate: true, set_aware_victims: false });
    });
}

#[test]
fn scheduler_without_preemption_invariants() {
    run("scheduler", 60, |g| {
        let cfg = SystemConfig::default();
        drive(g, &cfg, PatsScheduler { preemption: false, reallocate: false, set_aware_victims: false });
    });
}

#[test]
fn central_workstealer_invariants() {
    run("central stealer", 40, |g| {
        let cfg = SystemConfig::default();
        let ws = Workstealer::new(Mode::Central, g.bool(0.5), &cfg);
        drive(g, &cfg, ws);
    });
}

#[test]
fn decentral_workstealer_invariants() {
    run("decentral stealer", 40, |g| {
        let cfg = SystemConfig::default();
        let ws = Workstealer::new(Mode::Decentral, g.bool(0.5), &cfg);
        drive(g, &cfg, ws);
    });
}

#[test]
fn odd_topologies_hold_invariants() {
    // The paper uses 4 devices × 4 cores, but nothing in the scheduler may
    // assume it.
    run("odd topologies", 30, |g| {
        let mut cfg = SystemConfig::default();
        cfg.devices = g.usize(1, 7);
        cfg.cores_per_device = g.u64(2, 8) as u32;
        drive(g, &cfg, PatsScheduler { preemption: true, reallocate: true, set_aware_victims: false });
    });
}
