//! Stress tests for the persistent work-stealing executor
//! (`util::executor`): many producer threads hammering one pool with
//! random job sets while the workers steal from each other. The contract
//! under test is the executor's whole reason to exist — every submitted
//! job runs exactly once, no batch returns before its jobs finished, the
//! pool drains and re-parks cleanly between storms, and nested submission
//! from inside a job cannot deadlock.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use pats::util::executor::{current, Executor, Job};
use pats::util::rng::Rng;

/// N producer threads × M stealing workers over random batch sizes: every
/// job must execute exactly once (its slot goes 0 → 1, never 2), and every
/// `run` call must observe its own batch complete before returning.
#[test]
fn concurrent_producers_run_every_job_exactly_once() {
    const PRODUCERS: usize = 6;
    const BATCHES: usize = 40;
    const MAX_BATCH: u64 = 48;

    let pool = Executor::new(4);
    let handle = pool.handle();
    // One hit-counter slab per producer; slot (b, j) belongs to batch b's
    // j-th job. Sized for the worst case up front so slices are disjoint.
    let slabs: Vec<Vec<AtomicUsize>> = (0..PRODUCERS)
        .map(|_| (0..BATCHES * MAX_BATCH as usize).map(|_| AtomicUsize::new(0)).collect())
        .collect();
    let submitted: Vec<AtomicUsize> = (0..PRODUCERS).map(|_| AtomicUsize::new(0)).collect();

    std::thread::scope(|scope| {
        for (p, slab) in slabs.iter().enumerate() {
            let handle = handle.clone();
            let submitted = &submitted[p];
            scope.spawn(move || {
                let mut rng = Rng::seed_from_u64(0x9E37_79B9 + p as u64);
                for b in 0..BATCHES {
                    let n = rng.below(MAX_BATCH) as usize; // 0 included: empty batches are legal
                    let jobs: Vec<Job<'_>> = (0..n)
                        .map(|j| -> Job<'_> {
                            let slot = &slab[b * MAX_BATCH as usize + j];
                            Box::new(move || {
                                slot.fetch_add(1, Ordering::Relaxed);
                            })
                        })
                        .collect();
                    submitted.fetch_add(n, Ordering::Relaxed);
                    handle.run(jobs);
                    // The batch latch resolved: every one of *our* jobs has
                    // run (other producers' batches may still be in flight).
                    for j in 0..n {
                        assert_eq!(
                            slab[b * MAX_BATCH as usize + j].load(Ordering::Relaxed),
                            1,
                            "producer {p} batch {b} job {j} not exactly-once at latch"
                        );
                    }
                }
            });
        }
    });

    for (p, slab) in slabs.iter().enumerate() {
        let ran: usize = slab.iter().map(|s| s.load(Ordering::Relaxed)).sum();
        assert_eq!(
            ran,
            submitted[p].load(Ordering::Relaxed),
            "producer {p}: jobs lost or duplicated"
        );
        assert!(slab.iter().all(|s| s.load(Ordering::Relaxed) <= 1), "a job ran twice");
    }

    // The storm is over: the pool must have drained and re-parked, not
    // wedged — a fresh batch still completes, and drop joins every worker
    // (a stuck worker would hang the test here, failing it by timeout).
    let after = AtomicUsize::new(0);
    let jobs: Vec<Job<'_>> = (0..32)
        .map(|_| -> Job<'_> {
            let after = &after;
            Box::new(move || {
                after.fetch_add(1, Ordering::Relaxed);
            })
        })
        .collect();
    pool.run(jobs);
    assert_eq!(after.load(Ordering::Relaxed), 32, "pool wedged after the storm");
    drop(pool);
}

/// Random nested fan-outs: jobs submit sub-batches through the worker's
/// own installed handle (`executor::current()`), exactly how the scheduler
/// candidate-plan searches reach the pool from inside a sweep job. The
/// caller-helps protocol must keep arbitrary nesting deadlock-free, and
/// the grand total must account for every leaf exactly once.
#[test]
fn random_nested_fanouts_complete_without_deadlock() {
    let pool = Executor::new(3);
    let total = Arc::new(AtomicUsize::new(0));
    let mut expected = 0usize;
    let mut rng = Rng::seed_from_u64(0xDEAD_BEEF);

    for round in 0..20 {
        let outer = 1 + rng.below(6) as usize;
        let inner: Vec<usize> = (0..outer).map(|_| rng.below(9) as usize).collect();
        expected += inner.iter().map(|&i| 1 + i).sum::<usize>();
        let total = &total;
        let jobs: Vec<Job<'_>> = inner
            .iter()
            .map(|&n| -> Job<'_> {
                Box::new(move || {
                    total.fetch_add(1, Ordering::Relaxed);
                    // On a worker thread the pool's own handle is installed;
                    // fan the sub-jobs back into the same pool.
                    let pool = current().expect("worker thread has a handle installed");
                    let sub: Vec<Job<'_>> = (0..n)
                        .map(|_| -> Job<'_> {
                            Box::new(move || {
                                total.fetch_add(1, Ordering::Relaxed);
                            })
                        })
                        .collect();
                    pool.run(sub);
                })
            })
            .collect();
        pool.run(jobs);
        assert_eq!(
            total.load(Ordering::Relaxed),
            expected,
            "round {round}: nested jobs lost or duplicated"
        );
    }
}

/// A panicking job must not poison the pool for *other* producers: their
/// concurrent batches still complete exactly once, the panic reaches only
/// the submitter that owned the job, and the pool keeps working after.
#[test]
fn panic_in_one_batch_leaves_other_producers_unharmed() {
    let pool = Executor::new(2);
    let handle = pool.handle();
    let clean = AtomicUsize::new(0);

    std::thread::scope(|scope| {
        let panicker = {
            let handle = handle.clone();
            scope.spawn(move || {
                let jobs: Vec<Job<'_>> =
                    vec![Box::new(|| panic!("intentional test panic")) as Job<'_>];
                std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| handle.run(jobs)))
            })
        };
        let clean_ref = &clean;
        scope.spawn(move || {
            for _ in 0..30 {
                let jobs: Vec<Job<'_>> = (0..16)
                    .map(|_| -> Job<'_> {
                        Box::new(move || {
                            clean_ref.fetch_add(1, Ordering::Relaxed);
                        })
                    })
                    .collect();
                handle.run(jobs);
            }
        });
        assert!(panicker.join().unwrap().is_err(), "the panic must reach its submitter");
    });
    assert_eq!(clean.load(Ordering::Relaxed), 30 * 16, "bystander batches were disturbed");
}
