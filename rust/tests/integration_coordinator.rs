//! Integration: the controller end-to-end — request admission, the serial
//! job queue, policy interplay, preemption mid-flight, and state updates.

use pats::config::SystemConfig;
use pats::coordinator::Controller;
use pats::scheduler::PatsScheduler;
use pats::task::{DeviceId, FrameId, TaskState};
use pats::time::{SimDuration, SimTime};
use pats::workstealer::{Mode, Workstealer};

fn sched_controller(preemption: bool) -> Controller<PatsScheduler> {
    let mut cfg = SystemConfig::default();
    cfg.preemption = preemption;
    let policy = PatsScheduler::from_config(&cfg);
    Controller::new(cfg, policy)
}

#[test]
fn full_frame_flow_through_controller() {
    let mut c = sched_controller(true);
    let t0 = SimTime::from_millis(100);

    // Stage 2.
    let (hp, _dt, hp_out) = c.handle_hp_request(FrameId(0), DeviceId(0), t0);
    let hp_win = hp_out.window.expect("idle network");
    c.handle_state_update(hp, true, hp_win.end);
    assert_eq!(c.state.task(hp).unwrap().state, TaskState::Completed);

    // Stage 3: a 3-task set before the frame deadline.
    let deadline = t0 + SimDuration::from_secs_f64(18.86);
    let (rid, _dt, lp_out) =
        c.handle_lp_request(FrameId(0), DeviceId(0), 3, deadline, hp_win.end);
    assert!(lp_out.fully_allocated());
    assert_eq!(lp_out.placements.len(), 3);
    for p in &lp_out.placements {
        assert!(p.window.start >= hp_win.end);
        assert!(p.window.end <= deadline);
        c.handle_state_update(p.task, true, p.window.end);
    }
    let req = c.state.request(rid).unwrap();
    assert!(req
        .tasks
        .iter()
        .all(|t| c.state.task(*t).unwrap().state == TaskState::Completed));
    c.state.check_invariants().unwrap();
}

#[test]
fn preemption_fires_through_controller_under_contention() {
    let mut c = sched_controller(true);
    let t0 = SimTime::from_millis(10);
    let deadline = t0 + SimDuration::from_secs_f64(18.86);

    // Saturate device 1 with its own 4-task set (2 local × 2 cores fill it).
    let (_rid, _dt, lp_out) = c.handle_lp_request(FrameId(1), DeviceId(1), 4, deadline, t0);
    let local: u32 = lp_out
        .placements
        .iter()
        .filter(|p| p.device == DeviceId(1))
        .map(|p| p.cores)
        .sum();
    assert_eq!(local, 4, "source device saturated");

    // A stage-2 task on device 1 now needs preemption.
    let t1 = t0 + SimDuration::from_millis(500);
    let (hp, _dt, hp_out) = c.handle_hp_request(FrameId(2), DeviceId(1), t1);
    assert!(hp_out.allocated());
    let report = hp_out.preemption.expect("must preempt");
    let victim = c.state.task(report.victim).unwrap();
    // The victim either found a new home or failed terminally.
    assert!(
        victim.state == TaskState::Allocated
            || victim.state == TaskState::Failed(pats::task::FailReason::Preempted),
        "victim in {:?}",
        victim.state
    );
    assert_eq!(c.state.task(hp).unwrap().state, TaskState::Allocated);
    c.state.check_invariants().unwrap();
}

#[test]
fn controller_queue_accumulates_under_burst() {
    let mut c = sched_controller(false);
    let t = SimTime::ZERO;
    // Four simultaneous requests: each decision is pushed back by the
    // serial overhead of those before it (§3.3 blocking sequential queue).
    let mut decision_times = Vec::new();
    for d in 0..4u32 {
        let (_id, dt, _out) = c.handle_hp_request(FrameId(d as u64), DeviceId(d), t);
        decision_times.push(dt);
    }
    for pair in decision_times.windows(2) {
        assert!(pair[1] > pair[0], "decisions must serialise");
    }
    assert_eq!(c.jobs_processed, 4);
}

#[test]
fn workstealer_policy_through_controller() {
    let mut cfg = SystemConfig::default();
    cfg.preemption = true;
    let ws = Workstealer::new(Mode::Central, true, &cfg);
    let mut c = Controller::new(cfg, ws);
    let t0 = SimTime::from_millis(5);
    let deadline = t0 + SimDuration::from_secs_f64(18.86);

    // LP request enqueues (no immediate placements — poll-driven).
    let (rid, _dt, lp_out) = c.handle_lp_request(FrameId(0), DeviceId(0), 2, deadline, t0);
    assert!(lp_out.placements.is_empty());
    assert_eq!(c.policy.queued(), 2);

    // A poll on the source device pulls both tasks.
    use pats::scheduler::Policy as _;
    let cfg2 = c.cfg.clone();
    let placements = c.policy.poll(&mut c.state, &cfg2, DeviceId(0), t0);
    assert_eq!(placements.len(), 2);
    assert_eq!(c.policy.queued(), 0);

    // HP on the now-full device 0 must preempt and requeue the victim.
    let t1 = t0 + SimDuration::from_millis(100);
    let (_hp, _dt, hp_out) = c.handle_hp_request(FrameId(1), DeviceId(0), t1);
    assert!(hp_out.allocated());
    assert!(hp_out.preemption.is_some());
    assert_eq!(c.policy.queued(), 1, "victim requeued for a later steal");
    let _ = rid;
    c.state.check_invariants().unwrap();
}

#[test]
fn violation_update_releases_resources() {
    let mut c = sched_controller(true);
    let t0 = SimTime::ZERO;
    let deadline = SimTime::from_secs_f64(18.86);
    let (_rid, _dt, lp_out) = c.handle_lp_request(FrameId(0), DeviceId(2), 1, deadline, t0);
    let p = &lp_out.placements[0];
    // Device reports the task overran its window.
    c.handle_state_update(p.task, false, p.window.end);
    assert_eq!(
        c.state.task(p.task).unwrap().state,
        TaskState::Failed(pats::task::FailReason::Violated)
    );
    assert_eq!(c.state.device(p.device).len(), 0, "cores released");
    c.state.check_invariants().unwrap();
}

#[test]
fn hp_without_preemption_fails_cleanly_under_contention() {
    let mut c = sched_controller(false);
    let t0 = SimTime::ZERO;
    let deadline = SimTime::from_secs_f64(18.86);
    c.handle_lp_request(FrameId(0), DeviceId(3), 4, deadline, t0);
    let t1 = t0 + SimDuration::from_millis(200);
    let (hp, _dt, out) = c.handle_hp_request(FrameId(1), DeviceId(3), t1);
    assert!(!out.allocated());
    assert!(out.preemption.is_none());
    // The request left no resource residue for the failed task.
    assert!(c
        .state
        .device(DeviceId(3))
        .slots()
        .iter()
        .all(|s| s.task != hp));
    c.state.check_invariants().unwrap();
}
