//! Sharded-control-plane integration: `shards = 1` is provably
//! bit-identical to the raw pre-shard controller (same engine, both
//! surfaces, fingerprint + summary-stat equality), and K > 1 planes
//! conserve every frame and task across spill boundaries.

use pats::config::SystemConfig;
use pats::coordinator::{ControlSurface, Controller};
use pats::metrics::ScenarioMetrics;
use pats::scheduler::PatsScheduler;
use pats::shard::ControlPlane;
use pats::sim::run_with_surface_dynamic;
use pats::task::{DeviceId, FrameId};
use pats::time::SimTime;
use pats::trace::{ChurnEvent, ChurnScript, Distribution, FleetPattern, FleetProfile, Trace};

/// Counters that must match to the bit between the raw controller and the
/// 1-shard plane (wall-clock latency summaries excluded — they measure
/// real time, not simulated state).
fn assert_metrics_identical(a: &ScenarioMetrics, b: &ScenarioMetrics) {
    assert_eq!(a.frames_total, b.frames_total);
    assert_eq!(a.frames_completed, b.frames_completed);
    assert_eq!(a.frames_failed_hp, b.frames_failed_hp);
    assert_eq!(a.frames_failed_lp, b.frames_failed_lp);
    assert_eq!(a.frames_lost_churn, b.frames_lost_churn);
    assert_eq!(a.hp_generated, b.hp_generated);
    assert_eq!(a.hp_completed, b.hp_completed);
    assert_eq!(a.hp_failed_alloc, b.hp_failed_alloc);
    assert_eq!(a.hp_violated, b.hp_violated);
    assert_eq!(a.hp_orphaned, b.hp_orphaned);
    assert_eq!(a.hp_rescued, b.hp_rescued);
    assert_eq!(a.hp_lost_churn, b.hp_lost_churn);
    assert_eq!(a.lp_generated, b.lp_generated);
    assert_eq!(a.lp_completed, b.lp_completed);
    assert_eq!(a.lp_failed_alloc, b.lp_failed_alloc);
    assert_eq!(a.lp_failed_preempted, b.lp_failed_preempted);
    assert_eq!(a.lp_violated, b.lp_violated);
    assert_eq!(a.lp_offloaded, b.lp_offloaded);
    assert_eq!(a.lp_offloaded_completed, b.lp_offloaded_completed);
    assert_eq!(a.lp_sets_completed, b.lp_sets_completed);
    assert_eq!(a.lp_sets_total, b.lp_sets_total);
    assert_eq!(a.preemptions, b.preemptions);
    assert_eq!(a.realloc_success, b.realloc_success);
    assert_eq!(a.realloc_failure, b.realloc_failure);
    assert_eq!(a.preempted_by_cores, b.preempted_by_cores);
    assert_eq!(a.core_alloc_local, b.core_alloc_local);
    assert_eq!(a.core_alloc_offloaded, b.core_alloc_offloaded);
    // Float summaries to the bit: identical decisions fold identical
    // fractions in identical (key-sorted) order.
    assert_eq!(a.lp_set_fractions.count(), b.lp_set_fractions.count());
    assert_eq!(
        a.lp_set_fractions.mean().to_bits(),
        b.lp_set_fractions.mean().to_bits(),
        "set-fraction mean must be bit-identical"
    );
    assert_eq!(
        a.lp_set_fractions.std_dev().to_bits(),
        b.lp_set_fractions.std_dev().to_bits()
    );
    assert_eq!(a.accuracy_goodput.to_bits(), b.accuracy_goodput.to_bits());
    // A 1-shard plane has nowhere to spill.
    assert_eq!(b.lp_spill_attempts, 0);
    assert_eq!(b.lp_requests_spilled, 0);
}

/// Run the same engine against the raw controller and a 1-shard plane and
/// demand bit-identical final state + metrics.
fn assert_one_shard_equivalence(cfg: &SystemConfig, trace: &Trace, churn: &ChurnScript) {
    assert_eq!(cfg.sharding.shards, 1);
    let controller = Controller::new(cfg.clone(), PatsScheduler::from_config(cfg));
    let (raw, controller) = run_with_surface_dynamic(cfg, trace, churn, "raw", controller);
    let plane = ControlPlane::new(cfg, PatsScheduler::from_config);
    let (sharded, plane) = run_with_surface_dynamic(cfg, trace, churn, "plane", plane);
    assert_eq!(
        controller.fingerprint(),
        ControlSurface::fingerprint(&plane),
        "1-shard plane must leave a bit-identical network state"
    );
    plane.check_invariants().unwrap();
    assert_metrics_identical(&raw.metrics, &sharded.metrics);
}

#[test]
fn one_shard_plane_matches_raw_controller_on_the_seed_scenario() {
    // The paper's 4-device topology, uniform trace — the seed scenario.
    let mut cfg = SystemConfig::default();
    cfg.frames = 80;
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    assert_one_shard_equivalence(&cfg, &trace, &ChurnScript::none());
}

#[test]
fn one_shard_plane_matches_raw_controller_under_churn() {
    // Crash + drain + link degradation exercise every routed surface call
    // (failure detection, rescue, drain, rejoin, degradation broadcast).
    let mut cfg = SystemConfig::default();
    cfg.frames = 120;
    let trace =
        Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(30.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(45.0), ChurnEvent::Drain(DeviceId(2))),
        (SimTime::from_secs_f64(60.0), ChurnEvent::DegradeLink { factor: 0.7 }),
        (SimTime::from_secs_f64(90.0), ChurnEvent::RestoreLink),
    ]);
    assert_one_shard_equivalence(&cfg, &trace, &script);
}

#[test]
fn one_shard_plane_matches_raw_controller_on_a_256_device_fleet() {
    let mut cfg = SystemConfig::default();
    cfg.devices = 256;
    cfg.frames = 512;
    let profile = FleetProfile {
        pattern: FleetPattern::Diurnal { period_cycles: 16 },
        hp_only_pct: 50,
        lp_weight: 1,
    };
    let trace = Trace::generate_fleet(&profile, 256, 2, cfg.seed);
    assert_one_shard_equivalence(&cfg, &trace, &ChurnScript::none());
}

/// A deliberately over-committed workload on tiny shards: 4-task DNN sets
/// need 8 cores at the minimum configuration, which is an entire 2-device
/// shard — the second request of a cycle routinely finds its home shard
/// full and must spill (or return).
fn saturating_sharded_cfg(devices: usize, shards: usize) -> (SystemConfig, Trace) {
    let mut cfg = SystemConfig::default();
    cfg.devices = devices;
    cfg.sharding.shards = shards;
    let cycles = 4;
    cfg.frames = (devices * cycles) as u64;
    let profile =
        FleetProfile { pattern: FleetPattern::Steady, hp_only_pct: 0, lp_weight: 4 };
    let trace = Trace::generate_fleet(&profile, devices, cycles, cfg.seed);
    (cfg, trace)
}

#[test]
fn sharded_run_conserves_every_task_and_frame_across_spills() {
    let (cfg, trace) = saturating_sharded_cfg(8, 4);
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let (result, plane) =
        run_with_surface_dynamic(&cfg, &trace, &ChurnScript::none(), "shard-4", plane);
    let m = &result.metrics;
    plane.check_invariants().unwrap();
    assert!(m.lp_generated > 0);
    assert!(
        m.lp_spill_attempts > 0,
        "a saturated 2-device home shard must probe its siblings"
    );
    // Conservation: spill moves work between shards but every generated
    // task still ends in exactly one terminal account, and every frame in
    // exactly one bucket — nothing lost, nothing double-counted.
    assert_eq!(
        m.hp_completed + m.hp_failed_alloc + m.hp_violated + m.hp_lost_churn,
        m.hp_generated,
        "HP conservation across shards"
    );
    assert_eq!(
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated
            + m.lp_lost_churn,
        m.lp_generated,
        "LP conservation across spill boundaries"
    );
    assert_eq!(
        m.frames_completed + m.frames_failed_hp + m.frames_failed_lp + m.frames_lost_churn,
        m.frames_total,
        "frame accounting across shards"
    );
    // Spill bookkeeping is internally consistent.
    assert!(m.lp_spill_attempts >= m.lp_requests_spilled + m.lp_spill_returned);
    if m.lp_requests_spilled > 0 {
        assert!(m.lp_tasks_spilled >= m.lp_requests_spilled);
    }
    // Registry-level double-count audit: the per-shard registries are
    // disjoint and sum to the generated totals.
    let mut total_tasks = 0u64;
    let mut seen = std::collections::HashSet::new();
    for s in 0..plane.num_shards() {
        for rec in plane.shard(s).state.tasks() {
            assert!(seen.insert(rec.spec.id), "{:?} in two shards", rec.spec.id);
            total_tasks += 1;
        }
    }
    assert_eq!(total_tasks, m.hp_generated + m.lp_generated);
}

#[test]
fn sharded_runs_are_deterministic() {
    let (cfg, trace) = saturating_sharded_cfg(8, 4);
    let run = || {
        let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
        run_with_surface_dynamic(&cfg, &trace, &ChurnScript::none(), "det", plane)
    };
    let (a, pa) = run();
    let (b, pb) = run();
    assert_eq!(a.metrics.frames_completed, b.metrics.frames_completed);
    assert_eq!(a.metrics.lp_completed, b.metrics.lp_completed);
    assert_eq!(a.metrics.lp_requests_spilled, b.metrics.lp_requests_spilled);
    assert_eq!(a.metrics.lp_spill_attempts, b.metrics.lp_spill_attempts);
    assert_eq!(a.metrics.lp_spill_returned, b.metrics.lp_spill_returned);
    assert_eq!(
        ControlSurface::fingerprint(&pa),
        ControlSurface::fingerprint(&pb),
        "sharded final state is reproducible to the bit"
    );
}

#[test]
fn sharded_churn_rescue_stays_shard_local_and_accounted() {
    let (mut cfg, trace) = saturating_sharded_cfg(8, 2);
    cfg.hp_deadline_s = cfg.dynamics.hp_deadline_s; // rescue needs slack past detection
    let script = ChurnScript::from_events(vec![
        (SimTime::from_secs_f64(25.0), ChurnEvent::Crash(DeviceId(1))),
        (SimTime::from_secs_f64(40.0), ChurnEvent::Crash(DeviceId(6))),
    ]);
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let (result, plane) = run_with_surface_dynamic(&cfg, &trace, &script, "shard-churn", plane);
    let m = &result.metrics;
    plane.check_invariants().unwrap();
    assert_eq!(m.devices_crashed, 2);
    assert_eq!(m.failures_detected, 2);
    assert_eq!(m.hp_orphaned, m.hp_rescued + m.hp_lost_churn);
    assert_eq!(m.lp_orphaned, m.lp_rescued + m.lp_requeued_churn + m.lp_lost_churn);
    assert_eq!(
        m.lp_completed + m.lp_failed_alloc + m.lp_failed_preempted + m.lp_violated
            + m.lp_lost_churn,
        m.lp_generated,
        "LP conservation under churn + sharding"
    );
    // A rescued orphan may only land on a device of the crashed device's
    // own shard: rescue never crosses the shard boundary.
    for s in 0..plane.num_shards() {
        for rec in plane.shard(s).state.tasks() {
            if let Some(alloc) = &rec.allocation {
                assert_eq!(
                    plane.home_shard(alloc.device),
                    s,
                    "{:?} hosted outside its registry shard",
                    rec.spec.id
                );
            }
        }
    }
}

#[test]
fn broker_on_one_shard_plane_matches_raw_controller() {
    // With one shard the broker and rebalancer must go fully dormant:
    // enabling them at K=1 stays bit-identical to the raw pre-shard
    // controller (which has no broker at all).
    let mut cfg = SystemConfig::default();
    cfg.frames = 80;
    cfg.sharding.broker.enabled = true;
    cfg.sharding.rebalance.enabled = true;
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, cfg.frames, cfg.seed);
    assert_one_shard_equivalence(&cfg, &trace, &ChurnScript::none());
}

#[test]
fn broker_off_run_keeps_the_static_split_and_exports_no_broker_block() {
    // The default configuration must be indistinguishable from the
    // pre-broker control plane: even static leases throughout, no broker
    // counters in the metrics, no "broker" block in the exported JSON.
    let (cfg, trace) = saturating_sharded_cfg(8, 4);
    assert!(!cfg.sharding.broker.enabled && !cfg.sharding.rebalance.enabled);
    let plane = ControlPlane::new(&cfg, PatsScheduler::from_config);
    let (result, plane) =
        run_with_surface_dynamic(&cfg, &trace, &ChurnScript::none(), "no-broker", plane);
    let m = &result.metrics;
    assert!(!m.saw_broker());
    assert_eq!(m.broker_epochs, 0);
    assert_eq!(m.devices_migrated, 0);
    assert!(
        !m.deterministic_json().to_string_pretty().contains("\"broker\""),
        "broker-off JSON must not grow a broker block"
    );
    for &lease in plane.leases() {
        assert_eq!(lease.to_bits(), 0.25f64.to_bits(), "static 1/K lease at K=4");
    }
    plane.check_invariants().unwrap();
}

#[test]
fn scripted_call_sequence_matches_raw_controller_bit_for_bit() {
    // Controller-level (not sim-level) equivalence: drive both surfaces
    // through the identical scripted call sequence and compare state
    // fingerprints after every step.
    let cfg = SystemConfig::default();
    let mut raw = Controller::new(cfg.clone(), PatsScheduler::from_config(&cfg));
    let mut plane: ControlPlane<PatsScheduler> =
        ControlPlane::new(&cfg, PatsScheduler::from_config);
    let t = SimTime::from_secs_f64;

    let (ida, _, outa) = ControlSurface::handle_hp_request(&mut raw, FrameId(0), DeviceId(0), t(0.0));
    let (idb, _, outb) =
        ControlSurface::handle_hp_request(&mut plane, FrameId(0), DeviceId(0), t(0.0));
    assert_eq!(ida, idb);
    assert_eq!(outa.window, outb.window);
    assert_eq!(raw.fingerprint(), ControlSurface::fingerprint(&plane));

    let (ra, _, la) =
        ControlSurface::handle_lp_request(&mut raw, FrameId(0), DeviceId(1), 3, t(18.86), t(1.2));
    let (rb, _, lb) =
        ControlSurface::handle_lp_request(&mut plane, FrameId(0), DeviceId(1), 3, t(18.86), t(1.2));
    assert_eq!(ra, rb);
    assert_eq!(la.placements.len(), lb.placements.len());
    assert_eq!(raw.fingerprint(), ControlSurface::fingerprint(&plane));

    ControlSurface::handle_state_update(&mut raw, ida, true, outa.window.unwrap().end);
    ControlSurface::handle_state_update(&mut plane, idb, true, outb.window.unwrap().end);
    assert_eq!(raw.fingerprint(), ControlSurface::fingerprint(&plane));

    ControlSurface::handle_device_drain(&mut raw, DeviceId(2), t(3.0));
    ControlSurface::handle_device_drain(&mut plane, DeviceId(2), t(3.0));
    let fa = ControlSurface::handle_device_failure(&mut raw, DeviceId(1), t(5.0));
    let fb = ControlSurface::handle_device_failure(&mut plane, DeviceId(1), t(5.0));
    assert_eq!(fa.total(), fb.total());
    assert_eq!(raw.fingerprint(), ControlSurface::fingerprint(&plane));

    ControlSurface::handle_device_rejoin(&mut raw, DeviceId(1), t(8.0));
    ControlSurface::handle_device_rejoin(&mut plane, DeviceId(1), t(8.0));
    ControlSurface::prune_before(&mut raw, t(6.0));
    ControlSurface::prune_before(&mut plane, t(6.0));
    assert_eq!(raw.fingerprint(), ControlSurface::fingerprint(&plane));
    plane.check_invariants().unwrap();
}
