//! Transactional-plan properties.
//!
//! 1. **Atomicity**: for random scenes and random plan scripts, a
//!    validation failure injected at *every* stage index — and dropping
//!    the plan afterwards — leaves the network state bit-identical
//!    (fingerprint-equal) to the pre-plan state. This replaces the old
//!    ad-hoc "no partial commits leaked" assertions that were scattered
//!    across the policy files.
//! 2. **Equivalence**: single-task plans reproduce the seed paths'
//!    placements exactly on the paper's 4-device scenario — a direct
//!    reimplementation of the pre-plan mutate-and-rollback algorithms run
//!    on cloned resource timelines must pick the same device, window, and
//!    core configuration as the plan-based code.
//! 3. **Door enforcement**: no policy source file calls the raw mutation
//!    APIs; every placement goes through `NetworkState::apply`.

use pats::config::SystemConfig;
use pats::fidelity::{Catalog, VariantId};
use pats::resources::{CoreTimeline, SlotKind, Timeline};
use pats::scheduler::high_priority::HP_CORES;
use pats::scheduler::low_priority::allocate_single;
use pats::scheduler::plan::PlacementPlan;
use pats::scheduler::{PatsScheduler, Policy};
use pats::state::NetworkState;
use pats::task::{
    Allocation, CoreConfig, DeviceId, FrameId, Priority, TaskId, TaskSpec, Window,
};
use pats::time::{SimDuration, SimTime};
use pats::util::prop::{run, Gen};

// ---------------------------------------------------------------------
// Shared scene construction
// ---------------------------------------------------------------------

fn register(st: &mut NetworkState, source: u32, priority: Priority, deadline: SimTime) -> TaskId {
    let id = st.fresh_task_id();
    st.register_task(TaskSpec {
        id,
        frame: FrameId(0),
        source: DeviceId(source),
        priority,
        deadline,
        spawn: SimTime::ZERO,
        request: None,
    });
    id
}

/// Pre-load a valid random scene: some tasks placed, some still pending.
/// Returns (placed task ids, pending task ids).
fn random_scene(g: &mut Gen, cfg: &SystemConfig, st: &mut NetworkState) -> (Vec<TaskId>, Vec<TaskId>) {
    let mut placed = Vec::new();
    let mut pending = Vec::new();
    for _ in 0..g.usize(0, 8) {
        let dev = g.u64(0, cfg.devices as u64 - 1) as u32;
        let priority = if g.bool(0.25) { Priority::High } else { Priority::Low };
        let deadline = SimTime::from_secs_f64(g.f64(10.0, 90.0));
        let id = register(st, dev, priority, deadline);
        let start = SimTime::from_secs_f64(g.f64(0.0, 20.0));
        let dur = SimDuration::from_secs_f64(g.f64(0.5, 18.0));
        let cores = *g.pick(&[1u32, 2, 4]);
        let mut plan = PlacementPlan::new(st);
        let staged = plan.stage_placement(
            st,
            Allocation {
                task: id,
                device: DeviceId(dev),
                window: Window::from_duration(start, dur),
                cores,
                offloaded: false,
            },
        );
        if staged.is_ok() {
            st.apply(plan).unwrap();
            placed.push(id);
        } else {
            pending.push(id);
        }
    }
    for _ in 0..g.usize(1, 4) {
        let dev = g.u64(0, cfg.devices as u64 - 1) as u32;
        let deadline = SimTime::from_secs_f64(g.f64(10.0, 60.0));
        pending.push(register(st, dev, Priority::Low, deadline));
    }
    (placed, pending)
}

// ---------------------------------------------------------------------
// 1. Atomicity under injected validation failures
// ---------------------------------------------------------------------

/// One scripted staging operation.
#[derive(Clone, Copy)]
enum Op {
    Place { task_idx: usize, dev: u32, start_s: f64, dur_s: f64, cores: u32 },
    Link { task_idx: usize, not_before_s: f64, dur_ms: u64 },
    Evict { task_idx: usize },
}

fn exec(op: Op, plan: &mut PlacementPlan, st: &NetworkState, tasks: &[TaskId]) {
    match op {
        Op::Place { task_idx, dev, start_s, dur_s, cores } => {
            let _ = plan.stage_placement(
                st,
                Allocation {
                    task: tasks[task_idx % tasks.len()],
                    device: DeviceId(dev),
                    window: Window::from_duration(
                        SimTime::from_secs_f64(start_s),
                        SimDuration::from_secs_f64(dur_s),
                    ),
                    cores,
                    offloaded: false,
                },
            );
        }
        Op::Link { task_idx, not_before_s, dur_ms } => {
            plan.stage_link_earliest(
                st,
                SimTime::from_secs_f64(not_before_s),
                SimDuration::from_millis(dur_ms),
                SlotKind::LpAllocMsg,
                tasks[task_idx % tasks.len()],
            );
        }
        Op::Evict { task_idx } => {
            let _ = plan.stage_eviction(st, tasks[task_idx % tasks.len()], SimTime::ZERO);
        }
    }
}

/// Stage something guaranteed-invalid; assert it is rejected.
fn inject_failure(g: &mut Gen, plan: &mut PlacementPlan, st: &NetworkState, tasks: &[TaskId]) {
    match g.usize(0, 2) {
        0 => {
            // More cores than any device has.
            let err = plan.stage_placement(
                st,
                Allocation {
                    task: tasks[0],
                    device: DeviceId(0),
                    window: Window::from_duration(SimTime::ZERO, SimDuration::from_secs_f64(1.0)),
                    cores: 99,
                    offloaded: false,
                },
            );
            assert!(err.is_err(), "99-core placement must be rejected at staging");
        }
        1 => {
            // Evicting a task that does not exist.
            let err = plan.stage_eviction(st, TaskId(u64::MAX - 7), SimTime::ZERO);
            assert!(err.is_err(), "evicting an unknown task must be rejected");
        }
        _ => {
            // A link slot colliding with an already-staged/placed one.
            let w = plan.stage_link_earliest(
                st,
                SimTime::ZERO,
                SimDuration::from_millis(5),
                SlotKind::LpAllocMsg,
                tasks[0],
            );
            let err = plan.stage_link(
                st,
                w.start,
                SimDuration::from_millis(5),
                SlotKind::LpAllocMsg,
                tasks[0],
            );
            assert!(err.is_err(), "overlapping link slot must be rejected");
            // Clean the probe slot back out so the script continues from
            // where it was (unstaging is also part of the contract).
            assert!(plan.unstage_link_at(tasks[0], w.start));
        }
    }
}

#[test]
fn injected_failure_at_every_stage_index_leaves_state_bit_identical() {
    run("plan atomicity", 40, |g| {
        let cfg = SystemConfig::default();
        let mut st = NetworkState::new(&cfg);
        let (placed, pending) = random_scene(g, &cfg, &mut st);
        let tasks: Vec<TaskId> = placed.iter().chain(pending.iter()).copied().collect();
        if tasks.is_empty() {
            return;
        }
        // A random plan script.
        let n_ops = g.usize(1, 6);
        let script: Vec<Op> = (0..n_ops)
            .map(|_| match g.usize(0, 2) {
                0 => Op::Place {
                    task_idx: g.usize(0, tasks.len() - 1),
                    dev: g.u64(0, cfg.devices as u64 - 1) as u32,
                    start_s: g.f64(0.0, 30.0),
                    dur_s: g.f64(0.5, 18.0),
                    cores: *g.pick(&[1u32, 2, 4]),
                },
                1 => Op::Link {
                    task_idx: g.usize(0, tasks.len() - 1),
                    not_before_s: g.f64(0.0, 10.0),
                    dur_ms: g.u64(1, 50),
                },
                _ => Op::Evict { task_idx: g.usize(0, tasks.len() - 1) },
            })
            .collect();

        let before = st.fingerprint();
        // Poison at every stage index (and past the end), then drop the
        // plan: the state must be bit-identical every time.
        for poison_at in 0..=script.len() {
            let mut plan = PlacementPlan::new(&st);
            for (i, &op) in script.iter().enumerate() {
                if i == poison_at {
                    inject_failure(g, &mut plan, &st, &tasks);
                }
                exec(op, &mut plan, &st, &tasks);
            }
            if poison_at == script.len() {
                inject_failure(g, &mut plan, &st, &tasks);
            }
            assert_eq!(st.fingerprint(), before, "staging must never touch the state");
            drop(plan);
            assert_eq!(st.fingerprint(), before, "a dropped plan leaves zero residue");
        }

        // A stale plan is rejected whole.
        let mut stale = PlacementPlan::new(&st);
        for &op in &script {
            exec(op, &mut stale, &st, &tasks);
        }
        register(&mut st, 0, Priority::Low, SimTime::from_secs_f64(30.0));
        let poisoned_before = st.fingerprint();
        assert!(st.apply(stale).is_err(), "stale plan must be rejected");
        assert_eq!(st.fingerprint(), poisoned_before, "rejection leaves zero residue");

        // And the same script, committed, keeps every resource invariant.
        let mut plan = PlacementPlan::new(&st);
        for &op in &script {
            exec(op, &mut plan, &st, &tasks);
        }
        st.apply(plan).unwrap();
        st.check_invariants().unwrap();
    });
}

/// Variant-staging failure injection: degraded placements staged into a
/// plan obey exactly the same atomicity contract as full-fidelity ones —
/// a failed degraded staging call leaves the plan usable, a dropped plan
/// with staged degraded placements leaves the state bit-identical, and a
/// stale plan carrying degraded placements is rejected whole.
#[test]
fn rejected_degraded_plans_leave_state_bit_identical() {
    run("degraded plan atomicity", 40, |g| {
        let mut cfg = SystemConfig::default();
        cfg.fidelity.catalog = Catalog::demo();
        let mut st = NetworkState::new(&cfg);
        let (placed, pending) = random_scene(g, &cfg, &mut st);
        let tasks: Vec<TaskId> = placed.iter().chain(pending.iter()).copied().collect();
        if tasks.is_empty() {
            return;
        }
        let before = st.fingerprint();

        // A plan mixing degraded placements with an injected failure,
        // dropped: zero residue, bit-identical state.
        {
            let mut plan = PlacementPlan::new(&st);
            for (i, &task) in tasks.iter().enumerate() {
                let variant = VariantId((i % cfg.fidelity.catalog.lp.len()) as u8);
                let factor = cfg.fidelity.catalog.lp_variant(variant).time_factor;
                let _ = plan.stage_placement_at(
                    &st,
                    Allocation {
                        task,
                        device: DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32),
                        window: Window::from_duration(
                            SimTime::from_secs_f64(g.f64(0.0, 30.0)),
                            cfg.lp_slot_at(2, factor),
                        ),
                        cores: 2,
                        offloaded: false,
                    },
                    variant,
                );
            }
            // An infeasible degraded placement must be rejected at staging
            // without disturbing the plan's earlier staged ops.
            let err = plan.stage_placement_at(
                &st,
                Allocation {
                    task: tasks[0],
                    device: DeviceId(0),
                    window: Window::from_duration(SimTime::ZERO, cfg.lp_slot_at(2, 0.35)),
                    cores: 99,
                    offloaded: false,
                },
                VariantId(2),
            );
            assert!(err.is_err(), "99-core degraded placement must be rejected");
            assert_eq!(st.fingerprint(), before, "staging never touches the state");
            // Dropped here.
        }
        assert_eq!(st.fingerprint(), before, "dropped degraded plan leaves zero residue");

        // A committable degraded plan staged against a snapshot that then
        // moves on: rejected whole, bit-identical state.
        let mut stale = PlacementPlan::new(&st);
        let staged_any = tasks.iter().any(|&task| {
            stale
                .stage_placement_at(
                    &st,
                    Allocation {
                        task,
                        device: DeviceId(g.u64(0, cfg.devices as u64 - 1) as u32),
                        window: Window::from_duration(
                            SimTime::from_secs_f64(g.f64(40.0, 60.0)),
                            cfg.lp_slot_at(2, 0.6),
                        ),
                        cores: 2,
                        offloaded: false,
                    },
                    VariantId(1),
                )
                .is_ok()
        });
        register(&mut st, 0, Priority::Low, SimTime::from_secs_f64(90.0));
        let moved = st.fingerprint();
        if staged_any {
            assert!(st.apply(stale).is_err(), "stale degraded plan must be rejected");
        }
        assert_eq!(st.fingerprint(), moved, "rejection leaves zero residue");
        st.check_invariants().unwrap();
    });
}

// ---------------------------------------------------------------------
// 2. Seed-path equivalence on the paper's 4-device scenario
// ---------------------------------------------------------------------

/// Cloned resource view for the reference implementations: the seed's
/// algorithms mutated `NetworkState` directly; the references run the very
/// same mutation sequence against clones.
struct RefNet {
    link: Timeline,
    devs: Vec<CoreTimeline>,
}

impl RefNet {
    fn of(st: &NetworkState) -> RefNet {
        RefNet {
            link: st.link().clone(),
            devs: st.device_ids().map(|d| st.device(d).clone()).collect(),
        }
    }
}

/// The seed's high-priority `try_allocate`, verbatim semantics: earliest
/// allocation-message fit → window → capacity check → commit three slots.
fn ref_hp_allocate(
    net: &mut RefNet,
    cfg: &SystemConfig,
    st: &NetworkState,
    source: DeviceId,
    deadline: SimTime,
    task: TaskId,
    now: SimTime,
) -> Option<Window> {
    let msg_dur = st.link_model.slot_duration(cfg, SlotKind::HpAllocMsg);
    let msg_start = net.link.earliest_fit(now, msg_dur);
    let window = Window::from_duration(msg_start + msg_dur, cfg.hp_slot());
    if window.end > deadline {
        return None;
    }
    let dev = &net.devs[source.0 as usize];
    if !dev.fits(&window, HP_CORES) {
        return None;
    }
    net.link.reserve(msg_start, msg_dur, SlotKind::HpAllocMsg, task).unwrap();
    net.devs[source.0 as usize]
        .reserve(window, HP_CORES, task, deadline, false)
        .unwrap();
    let update_dur = st.link_model.slot_duration(cfg, SlotKind::StateUpdate);
    net.link
        .reserve_earliest(window.end, update_dur, SlotKind::StateUpdate, task);
    Some(window)
}

/// The seed's single-task low-priority path (`allocate_tasks` with one
/// task): time-point search, source-first partial allocation at MIN,
/// most-idle offload with mutate-and-rollback, then the improvement pass.
#[allow(clippy::too_many_arguments)]
fn ref_lp_single(
    net: &mut RefNet,
    cfg: &SystemConfig,
    st: &NetworkState,
    task: TaskId,
    source: DeviceId,
    deadline: SimTime,
    now: SimTime,
) -> Option<(DeviceId, Window, u32, bool)> {
    if now >= deadline {
        return None;
    }
    let cores = CoreConfig::MIN.cores();
    let slot = cfg.lp_slot(cores);
    let latest_start = deadline - slot;
    let mut time_points = vec![now];
    {
        let mut pts: Vec<SimTime> = net
            .devs
            .iter()
            .flat_map(|d| d.completion_points(now, deadline))
            .collect();
        pts.sort_unstable();
        pts.dedup();
        time_points.extend(pts);
    }
    time_points.retain(|&tp| tp <= latest_start);

    for tp in time_points {
        let msg_dur = st.link_model.slot_duration(cfg, SlotKind::LpAllocMsg);
        let msg_start = net.link.earliest_fit(now, msg_dur);
        let arrival = msg_start + msg_dur;

        // Source first.
        let local_window = Window::from_duration(arrival.max(tp), slot);
        if local_window.end <= deadline && net.devs[source.0 as usize].fits(&local_window, cores)
        {
            net.link.reserve(msg_start, msg_dur, SlotKind::LpAllocMsg, task).unwrap();
            net.devs[source.0 as usize]
                .reserve(local_window, cores, task, deadline, true)
                .unwrap();
            return Some(finish_ref_lp(net, cfg, st, task, source, deadline, local_window, false));
        }

        // Offload: most-idle first.
        let horizon = Window::new(tp, deadline.max(tp));
        let mut candidates: Vec<(u64, u32)> = Vec::new();
        for (i, dev) in net.devs.iter().enumerate() {
            if i == source.0 as usize {
                continue;
            }
            let busy: u64 = dev
                .overlapping(&horizon)
                .map(|s| s.window.duration().as_micros() * s.cores as u64)
                .sum();
            candidates.push((busy, i as u32));
        }
        candidates.sort_unstable();
        for (_, d) in candidates {
            let msg_w = net.link.reserve(msg_start, msg_dur, SlotKind::LpAllocMsg, task).unwrap();
            let xfer_dur = st.link_model.slot_duration(cfg, SlotKind::InputTransfer);
            let xfer_start = net.link.earliest_fit(msg_w.end, xfer_dur);
            let window = Window::from_duration((xfer_start + xfer_dur).max(tp), slot);
            if window.end <= deadline && net.devs[d as usize].fits(&window, cores) {
                net.link
                    .reserve(xfer_start, xfer_dur, SlotKind::InputTransfer, task)
                    .unwrap();
                net.devs[d as usize]
                    .reserve(window, cores, task, deadline, true)
                    .unwrap();
                return Some(finish_ref_lp(
                    net, cfg, st, task, DeviceId(d), deadline, window, true,
                ));
            }
            net.link.remove_owner_from(task, msg_start);
        }
    }
    None
}

/// The seed's improvement pass + state-update reservation.
#[allow(clippy::too_many_arguments)]
fn finish_ref_lp(
    net: &mut RefNet,
    cfg: &SystemConfig,
    st: &NetworkState,
    task: TaskId,
    dev: DeviceId,
    deadline: SimTime,
    window: Window,
    offloaded: bool,
) -> (DeviceId, Window, u32, bool) {
    let mut final_window = window;
    let mut final_cores = CoreConfig::MIN.cores();
    let next = CoreConfig::MIN.upgrade().unwrap();
    let upgraded = Window::from_duration(window.start, cfg.lp_slot(next.cores()));
    let d = &mut net.devs[dev.0 as usize];
    d.remove_task(task);
    if d.reserve(upgraded, next.cores(), task, deadline, true).is_ok() {
        final_window = upgraded;
        final_cores = next.cores();
    } else {
        d.reserve(window, CoreConfig::MIN.cores(), task, deadline, true)
            .expect("restoring the original reservation cannot fail");
    }
    let update_dur = st.link_model.slot_duration(cfg, SlotKind::StateUpdate);
    net.link
        .reserve_earliest(final_window.end, update_dur, SlotKind::StateUpdate, task);
    (dev, final_window, final_cores, offloaded)
}

#[test]
fn single_task_plans_reproduce_the_seed_paths_exactly() {
    run("plan/seed equivalence", 60, |g| {
        // The paper's 4-device scenario, randomly pre-loaded.
        let cfg = SystemConfig::default();
        let mut st = NetworkState::new(&cfg);
        random_scene(g, &cfg, &mut st);

        let now = SimTime::from_secs_f64(g.f64(0.0, 5.0));

        // High-priority equivalence.
        let hp_source = DeviceId(g.u64(0, 3) as u32);
        let hp_deadline = now + SimDuration::from_secs_f64(cfg.hp_deadline_s);
        let hp = register(&mut st, hp_source.0, Priority::High, hp_deadline);
        let mut reference = RefNet::of(&st);
        let expect =
            ref_hp_allocate(&mut reference, &cfg, &st, hp_source, hp_deadline, hp, now);
        let mut sched =
            PatsScheduler { preemption: false, reallocate: false, set_aware_victims: false };
        let got = sched.allocate_hp(&mut st, &cfg, hp, now);
        assert_eq!(got.window, expect, "HP plan diverges from the seed path");

        // Low-priority single-task equivalence (the §4 reallocation path).
        let lp_source = DeviceId(g.u64(0, 3) as u32);
        let lp_deadline = now + SimDuration::from_secs_f64(g.f64(6.0, 40.0));
        let lp = register(&mut st, lp_source.0, Priority::Low, lp_deadline);
        let mut reference = RefNet::of(&st);
        let expect =
            ref_lp_single(&mut reference, &cfg, &st, lp, lp_source, lp_deadline, now);
        let got = allocate_single(&mut st, &cfg, lp, now)
            .map(|p| (p.device, p.window, p.cores, p.offloaded));
        assert_eq!(got, expect, "LP single-task plan diverges from the seed path");

        // Both paths left a consistent state behind.
        st.check_invariants().unwrap();

        // And the committed resources match the reference's resources
        // exactly (same slots on the link and every device).
        if expect.is_some() {
            let mut actual_link: Vec<String> = st
                .link()
                .slots()
                .iter()
                .map(|s| format!("{:?}{:?}{:?}", s.window, s.kind, s.owner))
                .collect();
            let mut expect_link: Vec<String> = reference
                .link
                .slots()
                .iter()
                .map(|s| format!("{:?}{:?}{:?}", s.window, s.kind, s.owner))
                .collect();
            actual_link.sort();
            expect_link.sort();
            assert_eq!(actual_link, expect_link, "link calendars diverge");
        }
    });
}

// ---------------------------------------------------------------------
// 3. The plan door is the only door (grep-enforced)
// ---------------------------------------------------------------------

#[test]
fn no_direct_mutation_calls_outside_the_plan_door() {
    let root = env!("CARGO_MANIFEST_DIR");
    // Policy + driver sources: everything that builds plans. The state
    // module (which owns `apply` and the lifecycle methods) and the plan
    // module (which mutates only its own scratch copies) are the
    // sanctioned other side of the door.
    let policy_sources = [
        "rust/src/scheduler/mod.rs",
        "rust/src/scheduler/high_priority.rs",
        "rust/src/scheduler/low_priority.rs",
        "rust/src/scheduler/preemption.rs",
        "rust/src/scheduler/rescue.rs",
        "rust/src/workstealer/mod.rs",
        "rust/src/coordinator/mod.rs",
        "rust/src/sim/mod.rs",
        // The shard router moves registrations between shard-local states
        // and drives per-shard controllers; its mutations must flow
        // through the same doors.
        "rust/src/shard/mod.rs",
        // The multi-fidelity module defines catalog + gating only; the
        // degraded placements it enables must flow through the same plans.
        "rust/src/fidelity/mod.rs",
    ];
    // Raw mutation spellings that must not appear in policy code. The
    // compiler already enforces most of this (the link timeline is a
    // private field, `commit_allocation`/`reserve_link_message`/
    // `device_mut` no longer exist); the grep keeps the door shut against
    // reintroduction under the old names.
    let forbidden = [
        "commit_allocation",
        "reserve_link_message",
        "device_mut",
        ".link.reserve",
        "link_mut",
        "reserve_earliest",
    ];
    for file in policy_sources {
        let path = format!("{root}/{file}");
        let src = std::fs::read_to_string(&path).unwrap_or_else(|e| {
            panic!("cannot read {path}: {e} (grep-enforced door test)")
        });
        for needle in forbidden {
            assert!(
                !src.contains(needle),
                "{file} contains forbidden raw-mutation spelling `{needle}`; \
                 stage the operation in a PlacementPlan and commit it via \
                 NetworkState::apply instead"
            );
        }
    }
    // `charge_link_message` is the one sanctioned direct reservation — an
    // unconditional bookkeeping cost (workstealer polls). It must appear
    // in the workstealer and nowhere else among the policies.
    let ws = std::fs::read_to_string(format!("{root}/rust/src/workstealer/mod.rs")).unwrap();
    assert!(ws.contains("charge_link_message"), "polls pay their link cost");
    for file in policy_sources {
        if file.ends_with("workstealer/mod.rs") {
            continue;
        }
        let src = std::fs::read_to_string(format!("{root}/{file}")).unwrap();
        assert!(
            !src.contains("charge_link_message"),
            "{file}: charge_link_message is reserved for unconditional poll costs"
        );
    }
}
