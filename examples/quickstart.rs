//! Quickstart: one frame through the three-stage pipeline on real PJRT
//! inference, at every horizontal-partitioning width.
//!
//!     make artifacts && cargo run --release --example quickstart

use pats::runtime::{partition, Engine, Tensor};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Load the AOT-compiled model artifacts (built once by `make
    //    artifacts`; Python is not involved from here on).
    let engine = Engine::load(&Engine::default_dir())?;
    println!(
        "loaded {} executables on {}",
        engine.names().count(),
        engine.platform()
    );

    // 2. Synthesise a conveyor-belt frame: uniform background + one waste
    //    item.
    let background = Tensor::zeros(&[48, 48, 3]);
    let mut frame = background.clone();
    for h in 14..34 {
        for w in 10..30 {
            for c in 0..3 {
                frame.data[(h * 48 + w) * 3 + c] = 0.7 + 0.1 * c as f32;
            }
        }
    }

    // 3. Stage 1 — foreground object detector (always local, ~constant).
    let score = partition::run_detector(&engine, &frame, &background)?;
    println!("stage 1: foreground score {score:.4} -> object {}", score > 0.01);

    // 4. Stage 2 — high-priority low-complexity classifier.
    let decision = partition::run_classifier(&engine, &frame)?;
    println!(
        "stage 2: decision value {decision:.4} -> {}",
        if decision > 0.0 { "recyclable (spawn stage 3)" } else { "general waste" }
    );

    // 5. Stage 3 — high-complexity CNN at each core configuration. The
    //    outputs must agree: that is the §3.2 horizontal-partitioning
    //    invariant the scheduler relies on when it trades cores for
    //    latency.
    let mono = engine.execute("cnn_full", &[&frame])?;
    println!("stage 3 (monolithic): logits {:?} -> class {}", mono.data, mono.argmax());
    for tiles in [2usize, 4] {
        let t0 = std::time::Instant::now();
        let out = partition::run_cnn(&engine, &frame, tiles)?;
        println!(
            "stage 3 ({}-core cfg): class {} | max|Δ| vs monolithic {:.2e} | {:?}",
            tiles,
            out.argmax(),
            out.max_abs_diff(&mono),
            t0.elapsed()
        );
        assert!(out.max_abs_diff(&mono) < 2e-4);
    }
    println!("quickstart OK");
    Ok(())
}
