//! Run one full-scale paper scenario from a trace file (or a generated
//! distribution) and print its metrics — the per-scenario building block of
//! the experiments harness.
//!
//!     cargo run --release --example trace_experiment -- weighted4

use pats::config::SystemConfig;
use pats::sim::run_scenario;
use pats::trace::{Distribution, Trace};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let dist_name = std::env::args().nth(1).unwrap_or_else(|| "uniform".into());
    let dist = Distribution::parse(&dist_name)?;

    let mut cfg = SystemConfig::default();
    let trace = Trace::generate(dist, cfg.devices, cfg.frames, cfg.seed);
    let (lp, hp, frames) = trace.potential_counts();
    println!("trace {dist_name}: {frames} device-frames, potential HP {hp}, potential LP {lp}");

    // Preemption on vs off over the SAME trace — the paper's core A/B.
    for preemption in [true, false] {
        cfg.preemption = preemption;
        let label = if preemption { "preemption" } else { "no-preemption" };
        let result = run_scenario(&cfg, &trace, label);
        println!("\n{}", result.metrics.render_text());
        println!(
            "  virtual time {} simulated in {:.0?} wall",
            result.virtual_end, result.elapsed
        );
    }
    Ok(())
}
