//! §7.3 ablation: static startup throughput estimate vs the responsive EMA
//! estimator. The paper found "comparable performance ... which may
//! indicate that when padding is introduced the variation in network
//! throughput is negligible" — this driver reproduces that comparison.
//!
//!     cargo run --release --example bandwidth_ablation

use pats::config::{BandwidthEstimator, SystemConfig};
use pats::sim::run_scenario;
use pats::trace::{Distribution, Trace};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut cfg = SystemConfig::default();
    cfg.frames = 2048;
    let trace = Trace::generate(Distribution::Weighted(3), cfg.devices, cfg.frames, cfg.seed);

    println!("| estimator | frames % | HP % | LP % | offloaded % |");
    println!("|---|---|---|---|---|");
    for (name, est) in [("static", BandwidthEstimator::Static), ("ema", BandwidthEstimator::Ema)] {
        cfg.bandwidth_estimator = est;
        let m = run_scenario(&cfg, &trace, name).metrics;
        println!(
            "| {name} | {:.2} | {:.2} | {:.2} | {:.2} |",
            m.frame_completion_pct(),
            m.hp_completion_pct(),
            m.lp_completion_pct(),
            m.lp_offloaded_completion_pct(),
        );
    }
    println!("\nExpected (paper §7.3): the two rows are comparable — padding absorbs the variation.");
    Ok(())
}
