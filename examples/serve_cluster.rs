//! End-to-end serving driver: a real-clock mini edge cluster serving real
//! frames through real PJRT inference, coordinated by the paper's
//! preemption-aware scheduler.
//!
//! This is the proof that all three layers compose: the Rust coordinator
//! (L3) plans time-slotted placements; the placements execute the
//! AOT-compiled JAX model (L2) whose conv blocks are Pallas kernels (L1) —
//! horizontally partitioned exactly as the allocation's core configuration
//! dictates. Python is not running.
//!
//! Timings are calibrated: the stage benchmarks are *measured* on this
//! machine at startup (the paper benchmarks its stages on the RPi2B the
//! same way, §5), and the frame period is derived from them with the
//! paper's "minimum viable completion time" rule.
//!
//!     make artifacts && cargo run --release --example serve_cluster [frames]

use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::time::Instant;

use pats::config::SystemConfig;
use pats::coordinator::Controller;
use pats::runtime::{partition, Engine, Tensor};
use pats::scheduler::PatsScheduler;
use pats::task::{DeviceId, FrameId};
use pats::time::{Clock, RealClock, SimTime};
use pats::trace::{Distribution, Trace};
use pats::util::rng::Rng;
use pats::util::stats::Summary;

/// A wall-clock event in the serving loop.
#[derive(PartialEq, Eq, PartialOrd, Ord)]
struct Event {
    at: SimTime,
    seq: u64,
    kind: Kind,
}

#[derive(PartialEq, Eq, PartialOrd, Ord)]
enum Kind {
    Frame { cycle: usize, device: u32 },
}

fn make_frame(rng: &mut Rng, object: bool) -> (Tensor, Tensor) {
    let background = Tensor::zeros(&[48, 48, 3]);
    let mut frame = background.clone();
    if object {
        let h0 = rng.range_usize(2, 20);
        let w0 = rng.range_usize(2, 20);
        for h in h0..h0 + 16 {
            for w in w0..w0 + 16 {
                for c in 0..3 {
                    frame.data[(h * 48 + w) * 3 + c] = rng.range_f64(0.4, 1.0) as f32;
                }
            }
        }
    }
    (frame, background)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let frames_target: u64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(40);

    // ---- load + calibrate ------------------------------------------------
    let engine = Engine::load(&Engine::default_dir())?;
    println!("engine: {} executables on {}", engine.names().count(), engine.platform());

    let mut rng = Rng::seed_from_u64(7);
    let (frame, bg) = make_frame(&mut rng, true);
    let time_of = |f: &dyn Fn() -> ()| {
        let t0 = Instant::now();
        f();
        t0.elapsed().as_secs_f64()
    };
    // Warm-up once (first PJRT execution pays compilation/dispatch setup).
    partition::run_cnn(&engine, &frame, 2)?;
    let t_detector = time_of(&|| {
        partition::run_detector(&engine, &frame, &bg).unwrap();
    });
    let t_classifier = time_of(&|| {
        partition::run_classifier(&engine, &frame).unwrap();
    });
    let t_cnn2 = time_of(&|| {
        partition::run_cnn(&engine, &frame, 2).unwrap();
    });
    let t_cnn4_raw = time_of(&|| {
        partition::run_cnn(&engine, &frame, 4).unwrap();
    });
    // On a single-CPU host the 4-tile path has no parallel speed-up; model
    // the 4-core configuration with the paper's 2c/4c ratio so the
    // scheduler faces the paper's actual trade-off.
    let t_cnn4 = (t_cnn4_raw).min(t_cnn2 * 11.611 / 16.862);
    println!(
        "calibration: detector {:.1} ms | classifier {:.1} ms | cnn 2-tile {:.1} ms | 4-tile {:.1} ms (scheduled as {:.1} ms)",
        t_detector * 1e3, t_classifier * 1e3, t_cnn2 * 1e3, t_cnn4_raw * 1e3, t_cnn4 * 1e3
    );

    // ---- scaled config (the paper's §5 derivation) -------------------------
    // Floors keep windows well above OS sleep/jitter granularity: inference
    // on this host is orders of magnitude faster than on an RPi2B, so
    // slots are sized as if the stages ran at device-grade speeds while
    // the *real* inference executes comfortably inside them.
    let mut cfg = SystemConfig::default();
    cfg.stage1_s = t_detector.max(0.002);
    cfg.hp_proc_s = t_classifier.max(0.020);
    cfg.hp_proc_std_s = cfg.hp_proc_s * 0.5 + 0.002;
    cfg.lp_proc_2core_s = t_cnn2.max(0.150);
    cfg.lp_proc_4core_s = t_cnn4.max(0.100).min(cfg.lp_proc_2core_s);
    cfg.lp_proc_std_s = cfg.lp_proc_2core_s * 0.25;
    cfg.lp_live_extra_s = 0.0;
    // Minimum viable completion time: stage1 + hp + one 2-core DNN + slack.
    cfg.frame_period_s = (cfg.stage1_s + cfg.hp_proc_s + cfg.lp_proc_2core_s) * 1.6;
    cfg.hp_deadline_s = (cfg.hp_proc_s + cfg.hp_proc_std_s) * 4.0 + 0.05;
    cfg.controller_overhead_s = 0.0002;
    cfg.validate()?;
    println!(
        "scaled frame period: {:.1} ms ({} device-frames over {} devices)",
        cfg.frame_period_s * 1e3,
        frames_target,
        cfg.devices
    );

    // ---- cluster state -----------------------------------------------------
    let trace = Trace::generate(Distribution::Uniform, cfg.devices, frames_target, 11);
    let policy = PatsScheduler::from_config(&cfg);
    let mut controller = Controller::new(cfg.clone(), policy);
    let clock = RealClock::new();

    let mut events: BinaryHeap<Reverse<Event>> = BinaryHeap::new();
    let mut seq = 0u64;
    let period = cfg.frame_period_s;
    for cycle in 0..trace.cycles() {
        for d in 0..cfg.devices {
            let offset = if d >= cfg.devices / 2 { period / 2.0 } else { 0.0 };
            let at = SimTime::from_secs_f64(cycle as f64 * period + offset + d as f64 * 0.001);
            seq += 1;
            events.push(Reverse(Event { at, seq, kind: Kind::Frame { cycle, device: d as u32 } }));
        }
    }

    let mut hp_latency = Summary::new();
    let mut set_latency = Summary::new();
    let mut stage3_done = 0u64;
    let mut stage3_total = 0u64;
    let mut hp_done = 0u64;
    let mut hp_total = 0u64;
    let mut frames_completed = 0u64;
    let mut frames_with_work = 0u64;
    let mut preemptions = 0u64;
    let wall0 = Instant::now();

    while let Some(Reverse(ev)) = events.pop() {
        // Real-time pacing: sleep until the frame instant.
        loop {
            let now = clock.now();
            if now >= ev.at {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(
                (ev.at.as_micros() - now.as_micros()).min(5_000),
            ));
        }
        let Kind::Frame { cycle, device } = ev.kind;
        let load = trace.load_at(cycle, device as usize);
        let frame_id = FrameId((cycle * cfg.devices + device as usize) as u64);
        let t_frame = Instant::now();

        // Stage 1 — real detector inference.
        let (frame, bg) = make_frame(&mut rng, load.spawns_hp());
        let score = partition::run_detector(&engine, &frame, &bg)?;
        if !load.spawns_hp() || score < 1e-3 {
            frames_completed += 1; // empty belt: pipeline trivially done
            continue;
        }
        frames_with_work += 1;
        hp_total += 1;

        // Stage 2 — allocate through the controller, then run for real.
        let now = clock.now();
        let (hp_task, _t, hp_out) = controller.handle_hp_request(frame_id, DeviceId(device), now);
        let Some(_window) = hp_out.window else {
            continue; // stage-2 blocked: frame lost (counted via hp_total)
        };
        if hp_out.preemption.is_some() {
            preemptions += 1;
        }
        let _decision = partition::run_classifier(&engine, &frame)?;
        controller.handle_state_update(hp_task, true, clock.now());
        hp_done += 1;
        hp_latency.add(t_frame.elapsed().as_secs_f64() * 1e3);

        // Stage 3 — allocate the DNN set, then execute each placement with
        // the real partitioned CNN at its assigned core configuration.
        let n = load.lp_tasks();
        if n == 0 {
            frames_completed += 1;
            continue;
        }
        stage3_total += n as u64;
        let deadline = ev.at + pats::time::SimDuration::from_secs_f64(period);
        let (_rid, _t, lp_out) =
            controller.handle_lp_request(frame_id, DeviceId(device), n, deadline, clock.now());
        let mut all_ok = lp_out.unallocated.is_empty();
        let mut placements = lp_out.placements.clone();
        placements.sort_by_key(|p| p.window.start);
        for p in &placements {
            // Wait for the reserved window, then run the real inference at
            // the allocated width (2 or 4 tiles).
            loop {
                let now = clock.now();
                if now >= p.window.start {
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(
                    (p.window.start.as_micros() - now.as_micros()).min(2_000),
                ));
            }
            let tiles = p.cores as usize; // 2-core → 2 tiles, 4-core → 4 tiles
            let _logits = partition::run_cnn(&engine, &frame, tiles)?;
            let finished = clock.now();
            let ok = finished <= p.window.end;
            controller.handle_state_update(p.task, ok, finished);
            if ok {
                stage3_done += 1;
            } else {
                all_ok = false;
            }
        }
        if all_ok && !placements.is_empty() {
            frames_completed += 1;
            set_latency.add(t_frame.elapsed().as_secs_f64() * 1e3);
        }
    }

    // ---- report -------------------------------------------------------------
    let wall = wall0.elapsed().as_secs_f64();
    println!("\n=== serve_cluster report ===");
    println!("wall time: {wall:.2} s for {frames_target} device-frames ({frames_with_work} with objects)");
    println!(
        "frames completed end-to-end: {frames_completed}/{frames_target} ({:.1} %)",
        100.0 * frames_completed as f64 / frames_target as f64
    );
    println!(
        "stage-2 (high-priority): {hp_done}/{hp_total} | mean latency {:.1} ms | preemptions {preemptions}",
        hp_latency.mean()
    );
    println!(
        "stage-3 (DNN tasks): {stage3_done}/{stage3_total} within their windows | throughput {:.2} DNN/s",
        stage3_done as f64 / wall
    );
    let sl = set_latency;
    println!(
        "end-to-end frame latency (full sets): mean {:.1} ms, p95 {:.1} ms",
        sl.mean(),
        sl.percentile(95.0)
    );
    Ok(())
}
