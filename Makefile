# PATS build/verify entry points.
#
#   make verify      — tier-1 gate: release build + tests + format check
#                      (includes the engine-equivalence differential
#                      harness at its default shards=1,4 × both-engines
#                      sweep, plus the broker lease-invariant property
#                      tests and the re-sharding conservation tests)
#   make test-engines — the full differential matrix in one shot, the
#                      local equivalent of CI's test-matrix job (both
#                      broker axes: static split and broker+rebalance)
#   make lint        — clippy over every target, warnings denied
#   make bench       — micro-benchmarks (writes BENCH_*.json)
#   make bench-smoke — the same bench targets at CI-friendly reduced sizes
#                      (PATS_BENCH_SMOKE=1); same BENCH_*.json row shapes,
#                      used for the committed baselines
#   make bench-build — compile every bench target without running (CI gate
#                      so bench code cannot silently rot)
#   make profile     — one profiled fleet sweep via `pats fleet --profile`
#                      (per-phase wall-time breakdown on stderr)
#   make trace       — one traced seed run via `pats sim --trace`
#                      (deadline-miss attribution on stderr, Chrome +
#                      JSONL trace files under results/)
#   make artifacts   — AOT-compile the JAX model to HLO text (python layer)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: verify build test test-engines fmt lint bench bench-smoke bench-build profile trace artifacts

verify: build test fmt

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# The serial vs batched-parallel differential harness across the widest
# shard sweep, on both broker axes (CI runs the same harness one matrix
# cell at a time).
test-engines:
	PATS_EQ_SHARDS=1,2,4,8 PATS_EQ_ENGINE=both PATS_EQ_BROKER=off $(CARGO) test -q --test engine_equivalence
	PATS_EQ_SHARDS=1,2,4,8 PATS_EQ_ENGINE=both PATS_EQ_BROKER=on $(CARGO) test -q --test engine_equivalence
	PATS_EQ_SHARDS=1,2,4,8 PATS_EQ_ENGINE=both PATS_EQ_BROKER=off PATS_EQ_EXEC=auto $(CARGO) test -q --test engine_equivalence

fmt:
	$(CARGO) fmt --check

# Clippy + rustc warnings are denied, `missing_docs` included: the
# crate-wide #![warn(missing_docs)] burn-down is complete, so any new
# undocumented public item fails the gate.
lint:
	$(CARGO) clippy --all-targets -- -D warnings

bench:
	$(CARGO) bench --bench timeline
	$(CARGO) bench --bench alloc
	$(CARGO) bench --bench plan
	$(CARGO) bench --bench dynamics
	$(CARGO) bench --bench fidelity
	$(CARGO) bench --bench shards
	$(CARGO) bench --bench fleet
	$(CARGO) bench --bench obs
	$(CARGO) bench --bench executor

# Reduced-size smoke profile: same rows, CI-friendly sizes. The committed
# BENCH_*.json baselines come from this target.
bench-smoke:
	PATS_BENCH_SMOKE=1 $(CARGO) bench --bench shards
	PATS_BENCH_SMOKE=1 $(CARGO) bench --bench fleet
	PATS_BENCH_SMOKE=1 $(CARGO) bench --bench obs
	PATS_BENCH_SMOKE=1 $(CARGO) bench --bench executor

bench-build:
	$(CARGO) bench --no-run

# One profiled fleet sweep: per-phase wall-time breakdown on stderr.
profile:
	$(CARGO) run --release -- fleet --sizes 1024 --cycles 2 --profile

# One traced seed run: lifecycle flight recorder armed, deadline-miss
# attribution printed to stderr, Chrome about://tracing JSON + JSONL
# written next to each other under results/.
trace:
	mkdir -p results
	$(CARGO) run --release -- sim --dist uniform --trace results/trace.json --trace-summary

artifacts:
	$(PYTHON) python/compile/aot.py
