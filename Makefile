# PATS build/verify entry points.
#
#   make verify     — tier-1 gate: release build + tests + format check
#   make bench      — micro-benchmarks (writes BENCH_*.json)
#   make artifacts  — AOT-compile the JAX model to HLO text (python layer)

CARGO ?= cargo
PYTHON ?= python3

.PHONY: verify build test fmt bench artifacts

verify: build test fmt

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

fmt:
	$(CARGO) fmt --check

bench:
	$(CARGO) bench --bench timeline
	$(CARGO) bench --bench alloc
	$(CARGO) bench --bench dynamics

artifacts:
	$(PYTHON) python/compile/aot.py
