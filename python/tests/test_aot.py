"""AOT artifact pipeline: manifest consistency and HLO-text sanity.

These tests rebuild the artifacts into a temp dir (fast: lowering only, no
execution) and check the contract the Rust runtime parses.
"""

import os
import re

import pytest

from compile import aot, model

SHAPE_RE = re.compile(r"^f32\[[0-9,]+\]$")


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    names = aot.build(str(out), verbose=False)
    return out, names


def test_every_entry_point_emitted(built):
    out, names = built
    assert len(names) == len(aot.entry_points())
    for name in names:
        path = out / f"{name}.hlo.txt"
        assert path.exists() and path.stat().st_size > 0


def test_manifest_structure(built):
    out, names = built
    lines = (out / aot.MANIFEST_NAME).read_text().strip().split("\n")
    assert len(lines) == len(names)
    seen = set()
    for line in lines:
        name, fname, ins, outs = line.split("\t")
        assert name not in seen
        seen.add(name)
        assert fname == f"{name}.hlo.txt"
        assert ins.startswith("inputs=")
        assert outs.startswith("output=")
        for shape in ins[len("inputs="):].split(","):
            # shapes are comma-joined; re-join brackets by validating chunks
            pass
        assert SHAPE_RE.match(outs[len("output="):])


def test_hlo_text_is_parseable_shape(built):
    out, names = built
    for name in names:
        text = (out / f"{name}.hlo.txt").read_text()
        assert "ENTRY" in text, name
        assert "HloModule" in text, name


def test_tile_artifacts_cover_both_core_configs(built):
    _, names = built
    for i in range(len(model.BLOCK_CHANNELS)):
        assert f"block{i}_tile2" in names
        assert f"block{i}_tile4" in names
        assert f"block{i}_full" in names
        assert f"pool{i}" in names
    assert "head" in names and "cnn_full" in names
    assert "detector" in names and "classifier" in names


def test_manifest_shapes_match_model_geometry(built):
    out, _ = built
    lines = (out / aot.MANIFEST_NAME).read_text().strip().split("\n")
    by_name = {l.split("\t")[0]: l for l in lines}
    bs0 = model.block_shapes()[0]
    tile4 = by_name["block0_tile4"]
    h = bs0.tile_input_shape(4)
    assert f"inputs=f32[{h[0]},{h[1]},{h[2]}]" in tile4
    head = by_name["head"]
    hi = model.head_input_shape()
    assert f"inputs=f32[{hi[0]},{hi[1]},{hi[2]}]" in head
    assert f"output=f32[{model.NUM_CLASSES}]" in head


def test_rebuild_is_deterministic(built, tmp_path):
    """Same seed ⇒ byte-identical HLO (weights are baked constants)."""
    out, names = built
    out2 = tmp_path / "rebuild"
    aot.build(str(out2), verbose=False)
    name = "block1_tile2"
    a = (out / f"{name}.hlo.txt").read_text()
    b = (out2 / f"{name}.hlo.txt").read_text()
    assert a == b


def test_no_elided_constants(built):
    """Regression: the default HLO printer elides large constants as `{...}`,
    which the text parser re-materialises as ZEROS — the Rust runtime would
    silently run a zero-weight model. print_large_constants must stay on."""
    out, names = built
    for name in names:
        text = (out / f"{name}.hlo.txt").read_text()
        assert "{...}" not in text, f"{name} has elided constants"
