"""L2 model semantics and shape contracts."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


def test_geometry_constants():
    shapes = model.block_shapes()
    assert len(shapes) == len(model.BLOCK_CHANNELS)
    # H divisible by 4 tiles at every block (the 4-core config must exist).
    for bs in shapes:
        assert bs.h_in % 4 == 0 or bs.h_in % 2 == 0
        assert bs.h_in % 2 == 0 and bs.w_in % 2 == 0  # poolable
    assert model.head_input_shape() == (6, 6, 32)


def test_detector_zero_on_background():
    bg = rand(1, (model.IMG_H, model.IMG_W, model.IMG_C))
    score = model.detector(bg, bg)
    assert score.shape == (1,)
    assert float(score[0]) == 0.0


def test_detector_positive_on_object():
    bg = jnp.zeros((model.IMG_H, model.IMG_W, model.IMG_C), jnp.float32)
    frame = bg.at[10:20, 10:20, :].set(1.0)
    assert float(model.detector(frame, bg)[0]) > 0.0


def test_detector_monotone_in_object_size():
    bg = jnp.zeros((model.IMG_H, model.IMG_W, model.IMG_C), jnp.float32)
    small = bg.at[0:4, 0:4, :].set(1.0)
    large = bg.at[0:16, 0:16, :].set(1.0)
    assert float(model.detector(large, bg)[0]) > float(model.detector(small, bg)[0])


def test_features_shape_matches_classifier_weights():
    f = model.features(rand(2, (model.IMG_H, model.IMG_W, model.IMG_C)))
    w, b = model.classifier_params()
    assert f.shape == (w.shape[0],)
    assert b.shape == (1,)


def test_classifier_is_deterministic_scalar():
    x = rand(3, (model.IMG_H, model.IMG_W, model.IMG_C))
    a = model.classifier(x)
    b = model.classifier(x)
    assert a.shape == (1,)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_classifier_sign_varies_across_inputs():
    """The decision function must actually separate inputs, not be constant."""
    signs = set()
    for seed in range(16):
        x = rand(100 + seed, (model.IMG_H, model.IMG_W, model.IMG_C))
        signs.add(float(model.classifier(x)[0]) > 0)
        if len(signs) == 2:
            break
    assert len(signs) == 2


def test_cnn_head_logits():
    x = rand(4, model.head_input_shape())
    logits = model.cnn_head(x)
    assert logits.shape == (model.NUM_CLASSES,)


def test_cnn_forward_varies_with_input():
    a = model.cnn_forward(rand(5, (model.IMG_H, model.IMG_W, model.IMG_C)), tiles=1)
    b = model.cnn_forward(rand(6, (model.IMG_H, model.IMG_W, model.IMG_C)), tiles=1)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_weights_are_seed_stable():
    """Weights must be identical across processes: they are baked into the
    AOT artifacts once and the Python tests must agree with them."""
    w0, b0 = model.cnn_params()[0]
    # First few values pinned; a change means regenerating all artifacts.
    expected_mean = float(jnp.mean(w0))
    assert abs(expected_mean) < 0.05  # near-zero-mean init
    assert w0.shape == (3, 3, model.IMG_C, model.BLOCK_CHANNELS[0][1])
    w0b, _ = model.cnn_params()[0]
    np.testing.assert_array_equal(np.asarray(w0), np.asarray(w0b))
