"""The horizontal-partitioning equivalence invariant (paper §3.2).

Splitting a conv block's input into row tiles with halo, convolving each tile
independently, and stitching the outputs must reproduce the full-image SAME
convolution exactly — that is the property that lets the scheduler trade
cores for latency without changing results.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import conv2d, ref


def rand(key, shape):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32)


@settings(max_examples=15, deadline=None)
@given(
    tile_h=st.integers(1, 6),
    tiles=st.sampled_from([2, 3, 4]),
    w=st.integers(3, 10),
    cin=st.integers(1, 4),
    cout=st.integers(1, 4),
)
def test_tiled_conv_equals_full_conv(tile_h, tiles, w, cin, cout):
    h = tile_h * tiles
    x = rand(1, (h, w, cin))
    wt = rand(2, (3, 3, cin, cout))
    b = rand(3, (cout,))
    full = ref.conv2d_same_ref(x, wt, b)

    padded = ref.pad_h(x, model.HALO)
    tiles_in = ref.split_tiles_with_halo(padded, tiles, model.HALO)
    tiles_out = [conv2d.conv2d_validh(t, wt, b) for t in tiles_in]
    stitched = ref.stitch_tiles(tiles_out)

    assert stitched.shape == full.shape
    np.testing.assert_allclose(stitched, full, rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(tiles=st.sampled_from([2, 4]), seed=st.integers(0, 100))
def test_full_model_partition_equivalence(tiles, seed):
    """cnn_forward(x, tiles) == cnn_forward_ref(x) for the real model."""
    x = rand(seed, (model.IMG_H, model.IMG_W, model.IMG_C))
    got = model.cnn_forward(x, tiles=tiles)
    want = model.cnn_forward_ref(x)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=5e-4)


def test_split_stitch_roundtrip():
    x = rand(7, (12, 5, 2))
    padded = ref.pad_h(x, 1)
    tiles = ref.split_tiles_with_halo(padded, 4, 1)
    assert all(t.shape == (3 + 2, 5, 2) for t in tiles)
    # Dropping each tile's halo rows and stitching recovers the original.
    inner = [t[1:-1] for t in tiles]
    np.testing.assert_array_equal(np.asarray(ref.stitch_tiles(inner)), np.asarray(x))


def test_tile_shapes_match_manifest_geometry():
    """BlockShape's tile arithmetic is what aot.py exports and Rust relies on."""
    for bs in model.block_shapes():
        for tiles in (2, 4):
            th = bs.tile_h(tiles)
            assert th * tiles == bs.h_in
            assert bs.tile_input_shape(tiles) == (th + 2, bs.w_in, bs.c_in)
            assert bs.tile_output_shape(tiles) == (th, bs.w_in, bs.c_out)


def test_monolithic_equals_ref():
    x = rand(9, (model.IMG_H, model.IMG_W, model.IMG_C))
    np.testing.assert_allclose(
        model.cnn_forward(x, tiles=1), model.cnn_forward_ref(x), rtol=5e-4, atol=5e-4
    )
