"""L1 gate: every Pallas kernel against its pure-jnp oracle.

Hypothesis sweeps shapes (and dtypes for the dot-based kernels); explicit
cases pin the paper-relevant geometries (the exact tile shapes `aot.py`
exports). interpret=True keeps each case cheap but real.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import conv2d, matvec, maxpool
from compile.kernels import ref


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# conv2d
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(
    hout=st.integers(1, 10),
    w=st.integers(3, 12),
    cin=st.integers(1, 6),
    cout=st.integers(1, 8),
    kh=st.sampled_from([1, 3]),
    kw=st.sampled_from([1, 3]),
    relu=st.booleans(),
)
def test_conv2d_validh_matches_ref(hout, w, cin, cout, kh, kw, relu):
    hin = hout + kh - 1
    x = rand(1, (hin, w, cin))
    wt = rand(2, (kh, kw, cin, cout))
    b = rand(3, (cout,))
    got = conv2d.conv2d_validh(x, wt, b, relu=relu)
    want = ref.conv2d_validh_ref(x, wt, b)
    if relu:
        want = ref.relu_ref(want)
    assert got.shape == (hout, w, cout)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=15, deadline=None)
@given(
    h=st.integers(2, 12),
    w=st.integers(3, 12),
    cin=st.integers(1, 4),
    cout=st.integers(1, 6),
)
def test_conv2d_same_matches_ref(h, w, cin, cout):
    x = rand(4, (h, w, cin))
    wt = rand(5, (3, 3, cin, cout))
    b = rand(6, (cout,))
    got = conv2d.conv2d_same(x, wt, b)
    np.testing.assert_allclose(
        got, ref.conv2d_same_ref(x, wt, b), rtol=1e-4, atol=1e-4
    )


@pytest.mark.parametrize("block_h", [1, 2, 4, 8])
def test_conv2d_block_h_invariance(block_h):
    """Output must not depend on the grid decomposition."""
    x = rand(7, (10, 8, 3))
    wt = rand(8, (3, 3, 3, 4))
    b = rand(9, (4,))
    base = conv2d.conv2d_validh(x, wt, b, block_h=8)
    got = conv2d.conv2d_validh(x, wt, b, block_h=block_h)
    np.testing.assert_allclose(got, base, rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize(
    "tile_shape,cin,cout",
    [((26, 48), 3, 8), ((14, 48), 3, 8), ((14, 24), 8, 16),
     ((8, 24), 8, 16), ((8, 12), 16, 32), ((5, 12), 16, 32)],
)
def test_conv2d_paper_tile_geometries(tile_shape, cin, cout):
    """The exact tile shapes exported by aot.py for 2- and 4-core configs."""
    hin, w = tile_shape
    x = rand(10, (hin, w, cin))
    wt = rand(11, (3, 3, cin, cout))
    b = rand(12, (cout,))
    got = conv2d.conv2d_validh(x, wt, b, relu=True)
    want = ref.relu_ref(ref.conv2d_validh_ref(x, wt, b))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_conv2d_bf16_inputs_accumulate_in_f32():
    x = rand(13, (6, 6, 4)).astype(jnp.bfloat16)
    wt = rand(14, (3, 3, 4, 4)).astype(jnp.bfloat16)
    b = rand(15, (4,)).astype(jnp.bfloat16)
    got = conv2d.conv2d_validh(x, wt, b)
    want = ref.conv2d_validh_ref(
        x.astype(jnp.float32), wt.astype(jnp.float32), b.astype(jnp.float32)
    )
    assert got.dtype == jnp.bfloat16
    np.testing.assert_allclose(
        got.astype(jnp.float32), want, rtol=5e-2, atol=5e-2
    )


def test_conv2d_rejects_short_input():
    x = rand(16, (2, 5, 3))
    wt = rand(17, (3, 3, 3, 2))
    b = rand(18, (2,))
    with pytest.raises(AssertionError):
        conv2d.conv2d_validh(x, wt, b)


# ---------------------------------------------------------------------------
# maxpool
# ---------------------------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    h2=st.integers(1, 10),
    w2=st.integers(1, 10),
    c=st.integers(1, 8),
)
def test_maxpool_matches_ref(h2, w2, c):
    x = rand(19, (2 * h2, 2 * w2, c))
    got = maxpool.maxpool2x2(x)
    assert got.shape == (h2, w2, c)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.maxpool2x2_ref(x)))


def test_maxpool_odd_dims_rejected():
    with pytest.raises(AssertionError):
        maxpool.maxpool2x2(rand(20, (5, 4, 2)))


def test_maxpool_block_h_invariance():
    x = rand(21, (16, 8, 3))
    base = maxpool.maxpool2x2(x, block_h=8)
    for bh in (1, 2, 4):
        np.testing.assert_array_equal(
            np.asarray(maxpool.maxpool2x2(x, block_h=bh)), np.asarray(base)
        )


# ---------------------------------------------------------------------------
# matvec
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 64), m=st.integers(1, 16))
def test_matvec_matches_ref(n, m):
    x = rand(22, (n,))
    w = rand(23, (n, m))
    b = rand(24, (m,))
    got = matvec.matvec(x, w, b)
    np.testing.assert_allclose(got, ref.matvec_ref(x, w, b), rtol=1e-4, atol=1e-4)


def test_matvec_shape_mismatch_rejected():
    with pytest.raises(AssertionError):
        matvec.matvec(rand(25, (3,)), rand(26, (4, 2)), rand(27, (2,)))
