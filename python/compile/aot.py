"""AOT compile path: lower every model entry point to HLO *text* artifacts.

Run once by `make artifacts`; the Rust runtime (`rust/src/runtime/`) loads
the text with `HloModuleProto::from_text_file`, compiles it on the PJRT CPU
client, and executes it on the request path. Python is never invoked again.

HLO text — NOT `lowered.compiler_ir("hlo").serialize()` — is the interchange
format: jax >= 0.5 emits HloModuleProto with 64-bit instruction ids that the
xla crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example/README).

Every artifact fixes its weights as HLO constants (weights are generated from
a fixed seed in model.py), so executables take only image/feature tensors.

Usage: cd python && python -m compile.aot --out ../artifacts
"""

from __future__ import annotations

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

MANIFEST_NAME = "manifest.txt"


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (ids reassigned by the parser).

    `print_large_constants=True` is load-bearing: the default printer elides
    big constant tensors as `{...}`, which the HLO text parser silently
    re-materialises as ZEROS — the model would run with zero weights.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text(print_large_constants=True)


def _spec(shape: tuple[int, ...]) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def _fmt_shape(shape: tuple[int, ...]) -> str:
    return "f32[" + ",".join(str(d) for d in shape) + "]"


def entry_points() -> list[tuple[str, object, list[tuple[int, ...]], tuple[int, ...]]]:
    """(name, fn, input shapes, output shape) for every artifact.

    The return-value shape is the single tensor inside the 1-tuple the
    lowering emits (return_tuple=True).
    """
    img = (model.IMG_H, model.IMG_W, model.IMG_C)
    eps: list[tuple[str, object, list[tuple[int, ...]], tuple[int, ...]]] = [
        ("detector", lambda f, b: (model.detector(f, b),), [img, img], (1,)),
        ("classifier", lambda f: (model.classifier(f),), [img], (1,)),
        ("cnn_full", lambda f: (model.cnn_forward(f, tiles=1),), [img], (model.NUM_CLASSES,)),
    ]
    shapes = model.block_shapes()
    for i, bs in enumerate(shapes):
        block_in = (bs.h_in, bs.w_in, bs.c_in)
        block_out = (bs.h_in, bs.w_in, bs.c_out)
        eps.append(
            (
                f"block{i}_full",
                (lambda i_: lambda x: (model.cnn_block_full(x, i_),))(i),
                [block_in],
                block_out,
            )
        )
        for tiles in (2, 4):
            tin = bs.tile_input_shape(tiles)
            tout = bs.tile_output_shape(tiles)
            eps.append(
                (
                    f"block{i}_tile{tiles}",
                    (lambda i_: lambda x: (model.cnn_block_tile(x, i_),))(i),
                    [tin],
                    tout,
                )
            )
        eps.append(
            (
                f"pool{i}",
                lambda x: (model.cnn_pool(x),),
                [block_out],
                bs.pooled_shape(),
            )
        )
    head_in = model.head_input_shape()
    eps.append(("head", lambda x: (model.cnn_head(x),), [head_in], (model.NUM_CLASSES,)))
    return eps


def build(out_dir: str, verbose: bool = True) -> list[str]:
    """Lower every entry point into `out_dir`; returns the artifact names."""
    os.makedirs(out_dir, exist_ok=True)
    manifest_lines = []
    names = []
    for name, fn, in_shapes, out_shape in entry_points():
        specs = [_spec(s) for s in in_shapes]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        path = os.path.join(out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        ins = ",".join(_fmt_shape(s) for s in in_shapes)
        manifest_lines.append(f"{name}\t{fname}\tinputs={ins}\toutput={_fmt_shape(out_shape)}")
        names.append(name)
        if verbose:
            print(f"  {name}: {ins} -> {_fmt_shape(out_shape)} ({len(text)} chars)")
    with open(os.path.join(out_dir, MANIFEST_NAME), "w") as f:
        f.write("\n".join(manifest_lines) + "\n")
    if verbose:
        print(f"wrote {len(names)} artifacts + {MANIFEST_NAME} to {out_dir}")
    return names


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out", default="../artifacts", help="output directory")
    parser.add_argument("--quiet", action="store_true")
    args = parser.parse_args()
    build(args.out, verbose=not args.quiet)


if __name__ == "__main__":
    main()
