"""L1 Pallas kernel: 2x2/stride-2 max pooling.

This is the paper's reassembly barrier: horizontal partitioning processes
conv layers per-tile, but max-pool strides may not align with tile borders,
so tiles are stitched back together and pooled as one array (§3.2). The
kernel therefore always sees the full stitched feature map.

Grid over output row-blocks; each step reduces a (2*block_h, W, C) slab to
(block_h, W/2, C) with reshape-max — a pure VPU op on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _maxpool_kernel(x_ref, o_ref, *, block_h: int):
    i = pl.program_id(0)
    w = x_ref.shape[1]
    c = x_ref.shape[2]
    rows = x_ref[pl.dslice(i * 2 * block_h, 2 * block_h), :, :]
    o_ref[...] = rows.reshape(block_h, 2, w // 2, 2, c).max(axis=(1, 3))


def _pick_block_h(hout: int) -> int:
    for cand in (8, 6, 4, 3, 2, 1):
        if hout % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("block_h",))
def maxpool2x2(x: jax.Array, *, block_h: int | None = None) -> jax.Array:
    """2x2 max pooling, stride 2. x: (H, W, C), H and W even.

    Matches `ref.maxpool2x2_ref`.
    """
    h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"maxpool needs even dims, got {x.shape}"
    hout, wout = h // 2, w // 2
    bh = block_h or _pick_block_h(hout)
    assert hout % bh == 0
    return pl.pallas_call(
        functools.partial(_maxpool_kernel, block_h=bh),
        grid=(hout // bh,),
        in_specs=[pl.BlockSpec(x.shape, lambda i: (0, 0, 0))],
        out_specs=pl.BlockSpec((bh, wout, c), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hout, wout, c), x.dtype),
        interpret=True,
    )(x)
