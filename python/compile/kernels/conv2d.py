"""L1 Pallas kernel: tiled direct 2-D convolution (the horizontal-partitioning
hot spot of the paper's stage-3 CNN).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper partitions
conv inputs across *CPU cores* with halo rows exchanged over IPC. On TPU the
same insight becomes a Pallas grid over output row-blocks: each grid step
owns one row-block of the output in VMEM, reads the matching input rows plus
the (kh-1) halo rows, and expresses the convolution as kh*kw accumulated
matmuls of shape (block_h * W, Cin) @ (Cin, Cout) so the inner loop maps onto
the MXU instead of a scalar sliding window.

The kernel computes VALID over H / SAME over W: the caller pre-pads the W
axis (and, for the full-image flavour, the H axis) so tile semantics match
`ref.conv2d_validh_ref` exactly. `interpret=True` everywhere — the CPU PJRT
plugin cannot run Mosaic custom-calls; real-TPU efficiency is estimated
statically in DESIGN.md §Perf.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _conv_block_kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
                       block_h: int, relu: bool):
    """One grid step: compute `block_h` output rows.

    x_ref: (Hin, Wp, Cin) full (pre-padded-W) input — halo comes for free by
           reading `block_h + kh - 1` rows at the block offset.
    w_ref: (kh, kw, Cin, Cout); b_ref: (Cout,);
    o_ref: (block_h, Wout, Cout) this grid step's output block.
    """
    i = pl.program_id(0)
    wout = o_ref.shape[1]
    cout = o_ref.shape[2]
    cin = x_ref.shape[2]
    # Rows needed for this output block: block offset plus (kh-1) halo rows.
    x_rows = x_ref[pl.dslice(i * block_h, block_h + kh - 1), :, :]
    acc = jnp.zeros((block_h * wout, cout), dtype=jnp.float32)
    # kh*kw shifted sub-images, each contracted over Cin on the MXU.
    for ki in range(kh):
        for kj in range(kw):
            patch = jax.lax.dynamic_slice(
                x_rows, (ki, kj, 0), (block_h, wout, cin)
            ).reshape(block_h * wout, cin)
            acc = acc + jnp.dot(
                patch.astype(jnp.float32),
                w_ref[ki, kj, :, :].astype(jnp.float32),
                preferred_element_type=jnp.float32,
            )
    out = acc.reshape(block_h, wout, cout) + b_ref[...].astype(jnp.float32)
    if relu:
        out = jnp.maximum(out, 0.0)
    o_ref[...] = out.astype(o_ref.dtype)


def _pick_block_h(hout: int) -> int:
    """Largest divisor of `hout` no bigger than 8 — keeps each grid step's
    VMEM footprint bounded while amortising the halo re-read."""
    for cand in (8, 6, 4, 3, 2, 1):
        if hout % cand == 0:
            return cand
    return 1


@functools.partial(jax.jit, static_argnames=("relu", "block_h"))
def conv2d_validh(x: jax.Array, w: jax.Array, b: jax.Array, *,
                  relu: bool = False, block_h: int | None = None) -> jax.Array:
    """Convolution VALID over H, SAME over W (+bias, optional ReLU).

    x: (Hin, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,).
    Returns (Hin - kh + 1, W, Cout). Matches `ref.conv2d_validh_ref` (+ReLU).
    """
    kh, kw, cin, cout = w.shape
    hin, width, xc = x.shape
    assert xc == cin, f"channel mismatch {xc} != {cin}"
    hout = hin - kh + 1
    assert hout >= 1, f"input too short: {hin} rows for kh={kh}"
    # SAME over W: pre-pad the width axis.
    pad_l = (kw - 1) // 2
    pad_r = kw - 1 - pad_l
    xp = jnp.pad(x, ((0, 0), (pad_l, pad_r), (0, 0)))
    bh = block_h or _pick_block_h(hout)
    assert hout % bh == 0, f"block_h={bh} must divide Hout={hout}"
    grid = (hout // bh,)
    return pl.pallas_call(
        functools.partial(_conv_block_kernel, kh=kh, kw=kw, block_h=bh, relu=relu),
        grid=grid,
        in_specs=[
            # Full input visible to every grid step; the kernel slices its
            # rows + halo itself (BlockSpec cannot express overlapping reads).
            pl.BlockSpec(xp.shape, lambda i: (0, 0, 0)),
            pl.BlockSpec(w.shape, lambda i: (0, 0, 0, 0)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bh, width, cout), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((hout, width, cout), x.dtype),
        interpret=True,
    )(xp, w, b)


def conv2d_same(x: jax.Array, w: jax.Array, b: jax.Array, *,
                relu: bool = False) -> jax.Array:
    """Convolution SAME over H and W (+bias, optional ReLU).

    Implemented as H-padding + the VALID-H kernel, which is exactly the
    decomposition horizontal partitioning relies on.
    """
    kh = w.shape[0]
    pad_t = (kh - 1) // 2
    pad_b = kh - 1 - pad_t
    xp = jnp.pad(x, ((pad_t, pad_b), (0, 0), (0, 0)))
    return conv2d_validh(xp, w, b, relu=relu)
