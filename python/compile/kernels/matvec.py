"""L1 Pallas kernel: fused matvec + bias.

Used by the stage-2 "SVM" decision function and the stage-3 classification
head — both are y = x @ W + b over small feature vectors. A single grid step
suffices; the dot maps onto the MXU with an f32 accumulator.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(x_ref, w_ref, b_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)
    w = w_ref[...].astype(jnp.float32)
    y = jnp.dot(x[None, :], w, preferred_element_type=jnp.float32)[0]
    o_ref[...] = (y + b_ref[...].astype(jnp.float32)).astype(o_ref.dtype)


@jax.jit
def matvec(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """y = x @ w + b. x: (n,); w: (n, m); b: (m,). Matches `ref.matvec_ref`."""
    n, m = w.shape
    assert x.shape == (n,), f"shape mismatch {x.shape} vs {w.shape}"
    assert b.shape == (m,)
    return pl.pallas_call(
        _matvec_kernel,
        out_shape=jax.ShapeDtypeStruct((m,), x.dtype),
        interpret=True,
    )(x, w, b)
