"""Pure-jnp oracle implementations for every Pallas kernel.

These are the ground truth the build-time pytest suite checks the Pallas
kernels against (L1 correctness gate), and the reference the horizontal
partitioning equivalence invariant is stated in terms of.

Conventions: single image, NHWC without the N axis — i.e. arrays are
(H, W, C). Convolutions are 3x3 (or kh x kw), stride 1. "SAME" padding over
both axes for the full-image op; the tiled op uses VALID over H (the halo
rows supply the context) and SAME over W.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def conv2d_same_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """kh x kw convolution, stride 1, SAME padding on H and W, plus bias.

    x: (H, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,). Returns (H, W, Cout).
    """
    lhs = x[None].transpose(0, 3, 1, 2)  # NCHW
    rhs = w.transpose(3, 2, 0, 1)  # OIHW
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding="SAME"
    )
    return out[0].transpose(1, 2, 0) + b


def conv2d_validh_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Convolution VALID over H, SAME over W, plus bias.

    This is the per-tile flavour: the caller supplies (tile_h + kh - 1) rows
    (the halo) and receives tile_h rows back.

    x: (Hin, W, Cin); w: (kh, kw, Cin, Cout); b: (Cout,).
    Returns (Hin - kh + 1, W, Cout).
    """
    kw = w.shape[1]
    lhs = x[None].transpose(0, 3, 1, 2)
    rhs = w.transpose(3, 2, 0, 1)
    pad_w = ((kw - 1) // 2, kw - 1 - (kw - 1) // 2)
    out = jax.lax.conv_general_dilated(
        lhs, rhs, window_strides=(1, 1), padding=[(0, 0), pad_w]
    )
    return out[0].transpose(1, 2, 0) + b


def relu_ref(x: jax.Array) -> jax.Array:
    return jnp.maximum(x, 0.0)


def maxpool2x2_ref(x: jax.Array) -> jax.Array:
    """2x2 max pooling, stride 2. x: (H, W, C) with even H and W."""
    h, w, c = x.shape
    assert h % 2 == 0 and w % 2 == 0, f"maxpool needs even dims, got {x.shape}"
    x = x.reshape(h // 2, 2, w // 2, 2, c)
    return x.max(axis=(1, 3))


def matvec_ref(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """y = x @ w + b. x: (n,); w: (n, m); b: (m,)."""
    return x @ w + b


def pad_h(x: jax.Array, pad: int) -> jax.Array:
    """Zero-pad the H axis by `pad` rows on each side (SAME-conv context)."""
    return jnp.pad(x, ((pad, pad), (0, 0), (0, 0)))


def split_tiles_with_halo(x: jax.Array, tiles: int, halo: int) -> list[jax.Array]:
    """Horizontal partitioning: split the H axis of a pre-padded input.

    `x` must already be padded by `halo` rows top and bottom (see `pad_h`) so
    every tile — including the edge tiles — has uniform shape
    (tile_h + 2*halo, W, C). This mirrors the paper's §3.2: "partitions of
    input data ... expanding the partitions around the edges".
    """
    h_padded = x.shape[0]
    h = h_padded - 2 * halo
    assert h % tiles == 0, f"H={h} not divisible into {tiles} tiles"
    tile_h = h // tiles
    return [x[i * tile_h : i * tile_h + tile_h + 2 * halo] for i in range(tiles)]


def stitch_tiles(tile_outputs: list[jax.Array]) -> jax.Array:
    """Reassemble tile outputs along H (the paper's max-pool barrier)."""
    return jnp.concatenate(tile_outputs, axis=0)
