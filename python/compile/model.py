"""L2: the three-stage waste-classification pipeline as JAX compute graphs.

Stage 1 — foreground object detector: mean absolute difference of the frame
against a background plate (the paper's "simple foreground detection" on a
uniform-colour conveyor belt).

Stage 2 — high-priority low-complexity classifier: pooled features + a linear
("SVM"-style) decision function (the paper trains an SVM on SIFT features of
TrashNet; the scheduling system only cares that this runs in ~0.98 s locally).

Stage 3 — low-priority high-complexity CNN: a YoloV2-shaped stack of
conv+ReLU blocks separated by max-pool layers, classifying into the paper's
four recyclable classes. This is the stage that is horizontally partitioned:
conv blocks run per-tile (rows + halo), max-pool forces reassembly (§3.2).

All weights are generated from a fixed seed and *baked into the lowered HLO
as constants*, so the Rust runtime only feeds image tensors. Python never
runs on the request path; `aot.py` lowers every entry point here once.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import conv2d, matvec, maxpool
from compile.kernels import ref as kref

# ---------------------------------------------------------------------------
# Geometry. H is divisible by 4 tiles through every conv block; W stays even
# through all pools. Small on purpose: interpret-mode Pallas on CPU.
# ---------------------------------------------------------------------------

IMG_H = 48
IMG_W = 48
IMG_C = 3
#: (Cin, Cout) per conv block; a max-pool follows each block.
BLOCK_CHANNELS = [(IMG_C, 8), (8, 16), (16, 32)]
#: Classes of recyclable waste (paper: four).
NUM_CLASSES = 4
#: 3x3 convs → one halo row on each side of a tile.
HALO = 1
KH = KW = 3
#: Supported horizontal-partitioning widths (paper: two-core and four-core).
TILE_CONFIGS = (1, 2, 4)
#: Stage-2 feature grid (average-pooled patches).
FEAT_POOL = 8

WEIGHT_SEED = 0x7A57E


@dataclasses.dataclass(frozen=True)
class BlockShape:
    """Static geometry of one conv block at a given tile count."""

    h_in: int          # feature-map H entering the block
    w_in: int          # feature-map W entering the block
    c_in: int
    c_out: int

    def tile_h(self, tiles: int) -> int:
        assert self.h_in % tiles == 0, (self.h_in, tiles)
        return self.h_in // tiles

    def tile_input_shape(self, tiles: int) -> tuple[int, int, int]:
        """Shape of one tile *including halo rows* fed to the tile kernel."""
        return (self.tile_h(tiles) + 2 * HALO, self.w_in, self.c_in)

    def tile_output_shape(self, tiles: int) -> tuple[int, int, int]:
        return (self.tile_h(tiles), self.w_in, self.c_out)

    def pooled_shape(self) -> tuple[int, int, int]:
        return (self.h_in // 2, self.w_in // 2, self.c_out)


def block_shapes() -> list[BlockShape]:
    """Per-block geometry for the default image size."""
    shapes = []
    h, w = IMG_H, IMG_W
    for c_in, c_out in BLOCK_CHANNELS:
        shapes.append(BlockShape(h, w, c_in, c_out))
        h, w = h // 2, w // 2
    return shapes


def head_input_shape() -> tuple[int, int, int]:
    last = block_shapes()[-1]
    return last.pooled_shape()


# ---------------------------------------------------------------------------
# Weights — fixed seed, baked as constants at lowering time.
# ---------------------------------------------------------------------------


@functools.lru_cache(maxsize=1)
def cnn_params() -> list[tuple[jax.Array, jax.Array]]:
    """[(w, b)] per conv block, He-initialised from the fixed seed.

    `ensure_compile_time_eval` guards against being first called inside a
    jit trace (aot.py lowers functions that close over these weights): the
    cache must hold concrete constants, never tracers.
    """
    with jax.ensure_compile_time_eval():
        return _cnn_params_impl()


def _cnn_params_impl() -> list[tuple[jax.Array, jax.Array]]:
    key = jax.random.PRNGKey(WEIGHT_SEED)
    params = []
    for c_in, c_out in BLOCK_CHANNELS:
        key, kw_, kb_ = jax.random.split(key, 3)
        scale = (2.0 / (KH * KW * c_in)) ** 0.5
        w = jax.random.normal(kw_, (KH, KW, c_in, c_out), jnp.float32) * scale
        b = jax.random.normal(kb_, (c_out,), jnp.float32) * 0.01
        params.append((w, b))
    return params


@functools.lru_cache(maxsize=1)
def head_params() -> tuple[jax.Array, jax.Array]:
    """Dense head over the global-average-pooled last feature map."""
    with jax.ensure_compile_time_eval():
        return _head_params_impl()


def _head_params_impl() -> tuple[jax.Array, jax.Array]:
    c = BLOCK_CHANNELS[-1][1]
    key = jax.random.PRNGKey(WEIGHT_SEED + 1)
    kw_, kb_ = jax.random.split(key)
    w = jax.random.normal(kw_, (c, NUM_CLASSES), jnp.float32) * (1.0 / c) ** 0.5
    b = jax.random.normal(kb_, (NUM_CLASSES,), jnp.float32) * 0.01
    return w, b


@functools.lru_cache(maxsize=1)
def classifier_params() -> tuple[jax.Array, jax.Array]:
    """Stage-2 linear decision function over pooled features."""
    with jax.ensure_compile_time_eval():
        return _classifier_params_impl()


def _classifier_params_impl() -> tuple[jax.Array, jax.Array]:
    n = (IMG_H // FEAT_POOL) * (IMG_W // FEAT_POOL) * IMG_C
    key = jax.random.PRNGKey(WEIGHT_SEED + 2)
    kw_, kb_ = jax.random.split(key)
    w = jax.random.normal(kw_, (n, 1), jnp.float32) * (1.0 / n) ** 0.5
    b = jnp.zeros((1,), jnp.float32)
    return w, b


# ---------------------------------------------------------------------------
# Stage 1 — object detector.
# ---------------------------------------------------------------------------


def detector(frame: jax.Array, background: jax.Array) -> jax.Array:
    """Foreground score: mean |frame - background|. Scalar in a (1,) array."""
    return jnp.mean(jnp.abs(frame - background)).reshape(1)


# ---------------------------------------------------------------------------
# Stage 2 — high-priority low-complexity classifier.
# ---------------------------------------------------------------------------


def features(frame: jax.Array) -> jax.Array:
    """Average-pooled patch features (the stand-in for SIFT+SVM features)."""
    h, w, c = frame.shape
    p = FEAT_POOL
    pooled = frame.reshape(h // p, p, w // p, p, c).mean(axis=(1, 3))
    return pooled.reshape(-1)


def classifier(frame: jax.Array) -> jax.Array:
    """Stage-2 decision value: >0 ⇒ recyclable (spawn stage-3 tasks)."""
    w, b = classifier_params()
    return matvec.matvec(features(frame), w, b)


# ---------------------------------------------------------------------------
# Stage 3 — the horizontally-partitioned CNN.
# ---------------------------------------------------------------------------


def cnn_block_tile(x_tile: jax.Array, block_idx: int) -> jax.Array:
    """Conv+ReLU on one tile (rows + halo) of block `block_idx`.

    In: (tile_h + 2*HALO, W, Cin); out: (tile_h, W, Cout). This is the unit
    the scheduler spreads over cores; one AOT artifact exists per
    (block, tile-count) pair.
    """
    w, b = cnn_params()[block_idx]
    return conv2d.conv2d_validh(x_tile, w, b, relu=True)


def cnn_block_full(x: jax.Array, block_idx: int) -> jax.Array:
    """Conv+ReLU on the whole feature map (SAME padding) of block `block_idx`."""
    w, b = cnn_params()[block_idx]
    return conv2d.conv2d_same(x, w, b, relu=True)


def cnn_pool(x: jax.Array) -> jax.Array:
    """The reassembly barrier: max-pool over the stitched feature map."""
    return maxpool.maxpool2x2(x)


def cnn_head(x: jax.Array) -> jax.Array:
    """Global average pool + dense → 4-class logits."""
    w, b = head_params()
    pooled = x.mean(axis=(0, 1))
    return matvec.matvec(pooled, w, b)


def cnn_forward(x: jax.Array, tiles: int = 1) -> jax.Array:
    """End-to-end stage-3 forward at a given horizontal-partitioning width.

    tiles=1 is the monolithic path; tiles∈{2,4} mirrors the paper's two-core
    and four-core configurations: pad H, split into tiles + halo, conv each
    tile independently, stitch, pool — per block.
    """
    assert tiles in TILE_CONFIGS, tiles
    for i, shape in enumerate(block_shapes()):
        assert x.shape == (shape.h_in, shape.w_in, shape.c_in), (x.shape, shape)
        if tiles == 1:
            y = cnn_block_full(x, i)
        else:
            padded = kref.pad_h(x, HALO)
            tile_inputs = kref.split_tiles_with_halo(padded, tiles, HALO)
            tile_outputs = [cnn_block_tile(t, i) for t in tile_inputs]
            y = kref.stitch_tiles(tile_outputs)
        x = cnn_pool(y)
    return cnn_head(x)


def cnn_forward_ref(x: jax.Array) -> jax.Array:
    """Pure-jnp oracle of the full stage-3 forward (no Pallas anywhere)."""
    for i in range(len(BLOCK_CHANNELS)):
        w, b = cnn_params()[i]
        x = kref.maxpool2x2_ref(kref.relu_ref(kref.conv2d_same_ref(x, w, b)))
    w, b = head_params()
    return kref.matvec_ref(x.mean(axis=(0, 1)), w, b)
